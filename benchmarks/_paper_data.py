"""Published numbers from the paper's tables (mini-batch seconds) and the
calibration protocol shared by the table benchmarks.

Calibration: the paper's performance model consumes *empirically measured*
per-layer cuDNN runtimes (§V-A).  Without the paper's GPUs we fit the two
constants of the analytic surrogate — compute_efficiency (absolute scale)
and eff_halfwork (small-kernel saturation) — per model family, on ONE
column + ONE cell, and predict everything else.  The validated quantity is
the *communication/overlap/scaling structure* of the model (the paper's
contribution), not cuDNN absolute throughput.
"""
import dataclasses

import numpy as np

from repro.core import perfmodel as pm
from repro.core.distribution import Dist

TABLE1 = {  # 1K mesh model: N -> {GPUs/sample: seconds}
    4: {1: 0.403, 2: 0.2, 4: 0.121, 8: 0.0906, 16: 0.066},
    8: {1: 0.399, 2: 0.201, 4: 0.124, 8: 0.0829, 16: 0.0681},
    16: {1: 0.4, 2: 0.201, 4: 0.121, 8: 0.085, 16: 0.0739},
    32: {1: 0.401, 2: 0.207, 4: 0.123, 8: 0.0874, 16: 0.0794},
    64: {1: 0.407, 2: 0.208, 4: 0.124, 8: 0.0911, 16: 0.0839},
    128: {1: 0.407, 2: 0.209, 4: 0.125, 8: 0.0931, 16: 0.0902},
    256: {1: 0.401, 2: 0.209, 4: 0.127, 8: 0.0977},
    512: {1: 0.393, 2: 0.209, 4: 0.126},
    1024: {1: 0.4, 2: 0.211},
}

TABLE2 = {  # 2K mesh model: N -> {GPUs/sample: seconds}
    2: {2: 0.247, 4: 0.12, 8: 0.0859, 16: 0.0683},
    4: {2: 0.249, 4: 0.123, 8: 0.0895, 16: 0.0662},
    8: {2: 0.25, 4: 0.125, 8: 0.0849, 16: 0.0665},
    16: {2: 0.249, 4: 0.121, 8: 0.0848, 16: 0.0681},
    32: {2: 0.251, 4: 0.122, 8: 0.0851, 16: 0.0703},
    64: {2: 0.252, 4: 0.122, 8: 0.0856, 16: 0.0729},
    128: {2: 0.252, 4: 0.122, 8: 0.0867, 16: 0.0748},
    256: {2: 0.25, 4: 0.123, 8: 0.089},
    512: {2: 0.249, 4: 0.123},
}

TABLE3 = {  # ResNet-50: N -> {scheme: seconds}; schemes: 1 = sample
    # (32 samples/GPU), 2 = hybrid 32/2GPUs, 4 = hybrid 32/4GPUs
    128: {1: 0.106, 2: 0.0734, 4: 0.0593},
    256: {1: 0.106, 2: 0.0732, 4: 0.0671},
    512: {1: 0.105, 2: 0.0776, 4: 0.0617},
    1024: {1: 0.105, 2: 0.0747, 4: 0.0672},
    2048: {1: 0.108, 2: 0.0733, 4: 0.0651},
    4096: {1: 0.0984, 2: 0.078, 4: 0.066},
    8192: {1: 0.109, 2: 0.0785, 4: 0.0725},
    16384: {1: 0.108, 2: 0.0844, 4: 0.0792},
    32768: {1: 0.109, 2: 0.0869},
}

# GPUs/sample -> (H-ways, W-ways): 2-D splits beyond 2, matching 4 GPUs/node
SPLITS = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2), 16: (4, 4)}


def hybrid_dist(n_groups: int, hy: int, wx: int) -> tuple[Dist, dict]:
    mesh_shape = {"d": max(n_groups, 1), "mh": hy, "mw": wx}
    dims = {"N": ("d",)}
    if hy > 1:
        dims["H"] = ("mh",)
    if wx > 1:
        dims["W"] = ("mw",)
    return Dist(f"hybrid{hy}x{wx}", dims), mesh_shape


def predict(machine, layers, n_groups, gpus_per_sample):
    hy, wx = SPLITS[gpus_per_sample]
    d, ms = hybrid_dist(n_groups, hy, wx)
    return pm.network_cost(machine, layers, [d] * len(layers), ms)["total"]


def fit_machine(layer_fn, table, cells, group: int = 1, name="fit"):
    """Grid-fit (efficiency, halfwork) on the given (N, p) cells only.

    `group` = samples per GPU-group (1 for the mesh models: one sample
    spread over p GPUs; 32 for ResNet Table III's 32-samples-per-group).
    """
    best = None
    for eff in np.linspace(0.05, 0.8, 40):
        for fh in np.geomspace(1e8, 2e10, 40):
            m = dataclasses.replace(pm.LASSEN, compute_efficiency=eff,
                                    eff_halfwork=fh)
            err = 0.0
            for (N, p) in cells:
                t = table[N][p]
                pred = predict(m, layer_fn(N), N // group, p)
                err += (np.log(pred) - np.log(t)) ** 2
            if best is None or err < best[0]:
                best = (err, eff, fh)
    _, eff, fh = best
    return dataclasses.replace(pm.LASSEN, compute_efficiency=eff,
                               eff_halfwork=fh, name=name)
