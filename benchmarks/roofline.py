"""Roofline report from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, per-device memory.
Emits the markdown tables embedded in EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.roofline [--dir ...] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import registry

HBM_PER_CHIP = 16 * 2 ** 30     # v5e


def load(dir_):
    cells = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        d = json.load(open(f))
        if not d.get("ok"):
            continue
        key = (d["arch"], d["shape"], d["mesh"], d.get("variant", "base"))
        cells[key] = d
    return cells


def fmt_s(x):
    return f"{x*1e3:.2f}" if x < 10 else f"{x:.2f}e3"


def table(cells, mesh="16x16", variant="base", shapes=None, archs=None):
    shapes = shapes or list(registry.SHAPES)
    archs = archs or registry.ARCHS
    rows = []
    head = ("| arch | shape | compute ms | memory ms | collective ms | "
            "dominant | MF ratio | GiB/dev | fits |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for a in archs:
        for s in shapes:
            d = cells.get((a, s, mesh, variant))
            if not d:
                continue
            r = d["roofline_s"]
            peak = d["per_device"]["peak_bytes"]
            mf = d.get("model_flops_ratio")
            flag = " †" if a in registry.FULL_ATTN_500K and \
                s == "long_500k" else ""
            rows.append(
                f"| {a}{flag} | {s} | {fmt_s(r['compute'])} | "
                f"{fmt_s(r['memory'])} | {fmt_s(r['collective'])} | "
                f"{d['dominant']} | "
                f"{mf:.2f} |" if mf is not None else
                f"| {a}{flag} | {s} | {fmt_s(r['compute'])} | "
                f"{fmt_s(r['memory'])} | {fmt_s(r['collective'])} | "
                f"{d['dominant']} | n/a |")
            rows[-1] += f" {peak/2**30:.2f} | " \
                        f"{'yes' if peak <= HBM_PER_CHIP else 'NO'} |"
    return "\n".join(rows)


def summary(cells, variant="base"):
    """Pick hillclimb candidates: worst roofline fraction (most total time
    per useful model flop), most collective-bound, representative."""
    scored = []
    for (a, s, mesh, v), d in cells.items():
        if mesh != "16x16" or v != variant or a in registry.CNN_ARCHS:
            continue
        r = d["roofline_s"]
        total = sum(r.values())
        bound = max(r, key=r.get)
        coll_frac = r["collective"] / max(total, 1e-12)
        mf = d.get("model_flops_ratio", 0)
        scored.append((a, s, total, bound, coll_frac, mf,
                       d["per_device"]["peak_bytes"] / 2 ** 30))
    scored.sort(key=lambda t: -t[2])
    return scored


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    cells = load(args.dir)
    print(f"# single-pod (16x16) roofline — variant={args.variant}\n")
    print(table(cells, "16x16", args.variant))
    print(f"\n# multi-pod (2x16x16)\n")
    print(table(cells, "2x16x16", args.variant))
    print("\n# CNN (paper's own workloads)\n")
    print(table(cells, "16x16", args.variant, shapes=["cnn"],
                archs=registry.CNN_ARCHS))
    print("\n# hillclimb candidates (sorted by total roofline time)\n")
    for a, s, total, bound, cf, mf, gib in summary(cells, args.variant)[:10]:
        print(f"  {a:24s} {s:12s} total={total*1e3:8.1f}ms bound={bound:10s}"
              f" coll_frac={cf:.2f} mf_ratio={mf:.2f} {gib:.1f}GiB")


if __name__ == "__main__":
    main()
