"""Paper Table I: 1K mesh-model strong scaling (mini-batch time vs
GPUs/sample at fixed N).  Calibrate (eff, halfwork) on two cells —
(N=4, p=1) absolute scale and (N=4, p=16) saturation — predict the other
29 cells, report per-cell error.  CSV: name,us_per_call,derived."""
import numpy as np

from benchmarks import _paper_data as D
from repro.models.cnn import meshnet


def run(csv=True):
    layer_fn = lambda n: meshnet.layer_specs(meshnet.MESH1K, n)
    m = D.fit_machine(layer_fn, D.TABLE1, [(4, 1), (4, 16)], group=1,
                      name="lassen-mesh1k")
    rows, errs = [], []
    for N, row in D.TABLE1.items():
        for p, t in row.items():
            pred = D.predict(m, layer_fn(N), N, p)
            err = pred / t - 1
            if (N, p) not in [(4, 1), (4, 16)]:
                errs.append(abs(err))
            rows.append((f"table1/N{N}/p{p}", pred * 1e6,
                         f"paper={t*1e6:.0f}us err={err*100:+.1f}%"))
    rows.append(("table1/mean_abs_err_heldout", np.mean(errs) * 1e2,
                 f"eff={m.compute_efficiency:.3f} Fh={m.eff_halfwork:.2e}"))
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.1f},{d}")
    return rows, np.mean(errs)


if __name__ == "__main__":
    run()
