"""Paper Fig. 2: ResNet-50 layer microbenchmarks — conv1 (7x7/2, 224^2,
3->64) and res3b_branch2a (1x1, 28^2, 512->128) under sample vs spatial
parallelism, N in {1, 4, 32}.  Reports model-predicted FP and BP times per
decomposition; checks the figure's qualitative claims (sample cheapest per
comm; spatial wins for small N on the large-spatial layer; the 1x1 layer
saturates on kernel overheads).  CSV: name,us_per_call,derived."""
import dataclasses

from benchmarks import _paper_data as D
from repro.core import perfmodel as pm

CONV1 = pm.ConvLayer("conv1", n=1, c=3, h=224, w=224, f=64, k=7, s=2)
RES3B = pm.ConvLayer("res3b_branch2a", n=1, c=512, h=28, w=28, f=128,
                     k=1, s=1)


def run(csv=True):
    m = dataclasses.replace(pm.LASSEN, compute_efficiency=0.119,
                            eff_halfwork=1.49e9)
    rows = []
    checks = {}
    for layer in (CONV1, RES3B):
        for n in (1, 4, 32):
            base = None
            for p in (1, 2, 4, 8, 16):
                if p > 1 and (layer.h % D.SPLITS[p][0] or
                              layer.h // D.SPLITS[p][0] < layer.k):
                    continue
                hy, wx = D.SPLITS[p]
                d, ms = D.hybrid_dist(1, hy, wx)
                l = dataclasses.replace(layer, n=n)
                c = pm.layer_cost(m, l, d, ms)
                fp, bp = c.fp, c.bpx + c.bpw
                if p == 1:
                    base = fp + bp
                rows.append((f"fig2/{layer.name}/N{n}/p{p}/fp", fp * 1e6,
                             f"bp={bp*1e6:.1f}us "
                             f"speedup={(base/(fp+bp)):.2f}x"))
                checks[(layer.name, n, p)] = base / (fp + bp)
    # paper claims: conv1 N=1 ~1.35x at 8 GPUs; res3b fwd saturates early
    c1 = checks.get(("conv1", 1, 8), 0)
    rows.append(("fig2/check_conv1_8gpu_speedup", c1 * 100,
                 f"paper ~1.35x, model {c1:.2f}x"))
    r4 = checks.get(("res3b_branch2a", 1, 4), 0)
    r16 = checks.get(("res3b_branch2a", 1, 16), 0)
    rows.append(("fig2/check_res3b_saturates", (r16 - r4) * 100,
                 f"4->16 GPUs gains only {r16-r4:+.2f}x (saturation)"))
    if csv:
        for n_, v, d_ in rows:
            print(f"{n_},{v:.1f},{d_}")
    return rows


if __name__ == "__main__":
    run()
