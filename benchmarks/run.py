"""Benchmark harness: one function per paper table/figure + kernel micro +
roofline summary.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run
"""


def main() -> None:
    from benchmarks import (fig2_resnet_layers, fig3_mesh_layers,
                            hillclimb, kernels_micro, table1_mesh1k,
                            table2_mesh2k, table3_resnet50)
    print("name,us_per_call,derived")
    table1_mesh1k.run()
    table2_mesh2k.run()
    table3_resnet50.run()
    fig2_resnet_layers.run()
    fig3_mesh_layers.run()
    kernels_micro.run()
    hillclimb.run()
    # roofline summary from dry-run artifacts (if present)
    try:
        from benchmarks import roofline
        cells = roofline.load("benchmarks/artifacts/dryrun")
        for (a, s, mesh, v), d in sorted(cells.items()):
            if v != "base":
                continue
            r = d["roofline_s"]
            dom = d["dominant"]
            print(f"roofline/{a}/{s}/{mesh},{r[dom]*1e6:.1f},"
                  f"dominant={dom} mf_ratio="
                  f"{d.get('model_flops_ratio', float('nan')):.2f}")
    except Exception as e:  # artifacts not generated yet
        print(f"roofline/skipped,0,{type(e).__name__}")


if __name__ == '__main__':
    main()
