"""Paper Fig. 3: 2K mesh-model layer microbenchmarks — conv1_1 (3x3/2,
2048^2, 18->64) and conv6_1 (3x3/2, 64^2, 512->512) under spatial
parallelism, N in {1, 2, 4}.  Claims to reproduce: conv1_1 achieves
~14.8x at 16 GPUs (halo hidden); conv6_1 still ~1.4x at N=1.
CSV: name,us_per_call,derived."""
import dataclasses

from benchmarks import _paper_data as D
from repro.core import perfmodel as pm

CONV1_1 = pm.ConvLayer("conv1_1", n=1, c=18, h=2048, w=2048, f=64, k=3, s=2)
CONV6_1 = pm.ConvLayer("conv6_1", n=1, c=512, h=64, w=64, f=512, k=3, s=2)


def run(csv=True):
    m = dataclasses.replace(pm.LASSEN, compute_efficiency=0.119,
                            eff_halfwork=1.49e9)
    rows, checks = [], {}
    for layer in (CONV1_1, CONV6_1):
        for n in (1, 2, 4):
            base = None
            for p in (1, 2, 4, 8, 16):
                hy, wx = D.SPLITS[p]
                if layer.h % hy or layer.w % wx or \
                        layer.h // hy < layer.k:
                    continue
                d, ms = D.hybrid_dist(1, hy, wx)
                l = dataclasses.replace(layer, n=n)
                c = pm.layer_cost(m, l, d, ms)
                tot = c.fp + c.bpx + c.bpw
                if p == 1:
                    base = tot
                sp = base / tot
                rows.append((f"fig3/{layer.name}/N{n}/p{p}", tot * 1e6,
                             f"speedup={sp:.2f}x"))
                checks[(layer.name, n, p)] = sp
    c11 = checks.get(("conv1_1", 1, 16), 0)
    rows.append(("fig3/check_conv1_1_16gpu", c11 * 100,
                 f"paper ~14.8x, model {c11:.1f}x"))
    c61 = checks.get(("conv6_1", 1, 16), 0)
    rows.append(("fig3/check_conv6_1_16gpu", c61 * 100,
                 f"paper ~1.4x (continued benefit), model {c61:.1f}x"))
    if csv:
        for n_, v, d_ in rows:
            print(f"{n_},{v:.1f},{d_}")
    return rows


if __name__ == "__main__":
    run()
