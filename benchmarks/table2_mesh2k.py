"""Paper Table II: 2K mesh-model strong scaling (baseline 2 GPUs/sample —
pure sample parallelism exceeds GPU memory, the paper's memory headline).
Per-model calibration on (N=2,p=2) + (N=2,p=16); predict the other cells.
CSV: name,us_per_call,derived."""
import numpy as np

from benchmarks import _paper_data as D
from repro.models.cnn import meshnet


def run(csv=True):
    layer_fn = lambda n: meshnet.layer_specs(meshnet.MESH2K, n)
    m = D.fit_machine(layer_fn, D.TABLE2, [(2, 2), (2, 16)], group=1,
                      name="lassen-mesh2k")
    rows, errs = [], []
    for N, row in D.TABLE2.items():
        for p, t in row.items():
            pred = D.predict(m, layer_fn(N), N, p)
            err = pred / t - 1
            if (N, p) not in [(2, 2), (2, 16)]:
                errs.append(abs(err))
            rows.append((f"table2/N{N}/p{p}", pred * 1e6,
                         f"paper={t*1e6:.0f}us err={err*100:+.1f}%"))
    rows.append(("table2/mean_abs_err_heldout", np.mean(errs) * 1e2,
                 f"eff={m.compute_efficiency:.3f} Fh={m.eff_halfwork:.2e}"))
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.1f},{d}")
    return rows, np.mean(errs)


if __name__ == "__main__":
    run()
