"""Kernel microbenchmarks: wall-clock of the jit'd reference paths on CPU
(the semantic implementations the Pallas kernels must match), plus
model-predicted TPU-v5e times for the same shapes from the roofline.
CSV: name,us_per_call,derived."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_fn as _time
from repro.kernels import ref

PEAK = 197e12
BW = 819e9


def run(csv=True):
    rows = []
    key = jax.random.PRNGKey(0)
    # conv2d: a mesh-model block-1 shard (paper hot spot)
    x = jax.random.normal(key, (1, 130, 128, 64), jnp.float32)
    w = jax.random.normal(key, (3, 3, 64, 64), jnp.float32) * 0.1
    f = jax.jit(lambda x, w: ref.conv2d_ref(x, w))
    t = _time(f, x, w)
    flops = 2 * 128 * 126 * 64 * 9 * 64
    rows.append(("kernel/conv2d_cpu_ref", t * 1e6,
                 f"tpu_pred={max(flops/PEAK, 4*x.size/BW)*1e6:.1f}us"))
    # flash attention: one ring-step tile
    q = jax.random.normal(key, (1, 256, 16, 128), jnp.bfloat16)
    k = jax.random.normal(key, (1, 256, 8, 128), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    t = _time(f, q, k, k)
    flops = 4 * 256 * 256 * 16 * 128
    rows.append(("kernel/flash_cpu_ref", t * 1e6,
                 f"tpu_pred={max(flops/PEAK, 2*3*q.size/BW)*1e6:.1f}us"))
    # ssd chunk
    xdt = jax.random.normal(key, (1, 128, 24, 64), jnp.float32) * 0.5
    la = -jax.random.uniform(key, (1, 128, 24), minval=0.01, maxval=0.5)
    B = jax.random.normal(key, (1, 128, 128), jnp.float32) * 0.5
    f = jax.jit(lambda a, b, c, d: ref.ssd_chunk_ref(a, b, c, d))
    t = _time(f, xdt, la, B, B)
    rows.append(("kernel/ssd_chunk_cpu_ref", t * 1e6, ""))
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.1f},{d}")
    return rows


if __name__ == "__main__":
    run()
