"""Uniform vs solved per-layer plans: measured step time cross-checked
against the §V perf model — the validation loop the paper closes with
(predicted vs measured, Table I-III), now on *calibrated* costs.

  PYTHONPATH=src python -m benchmarks.strategy_exec [ndevices] \
      [--out BENCH_strategy.json] [--calibration BENCH_calibration.json] \
      [--gate] [--gate-tol 0.10] [--reps N] [--attribute] [--audit] \
      [--search beam:4] [--ratio-tol 10] [--ratio-warn-only]

Three gates ride on the measurements: the ordering promise (solved auto
plans measure no slower than their uniform baselines), the widened-search
promise (the wide-candidate beam/hillclimb plan measures no slower than
the greedy solve on at least one workload), and the model-fidelity gate
(the composed-calibrated model/measured ratio on mesh16cf/mesh16_proxy
stays within --ratio-tol of 1.0; the same plans are also re-priced
through the factor-free analytic view so BENCH_strategy.json records
whether composition calibration tightened the ratio).  With --attribute,
per-term drift additionally feeds calibrate.refit_from_attribution so
the next run's factors absorb the measured drift.

Runs on `ndevices` host CPU devices (default 4, set before jax import).
First the §V cost inputs are calibrated on the live backend
(core.calibrate: local-conv EmpiricalTable over the workloads' shard
shapes, fitted α/β and roofline constants; written to --calibration so CI
uploads it and later runs reuse it), then three workloads execute:

  * mesh128 — the strategy-choice workload from PR 1: uniform hybrid vs
    the §V-C solved auto plan (per-layer dists + reshard points);
  * mesh16cf — a small-spatial, channel-heavy meshnet where the solver
    picks §III-D channel/filter layers: cross-checks the perf model's CF
    cost terms (reduce-scatter fwd, all-gather BPw) against the
    core.channel_conv runtime, and A/Bs auto-with-CF vs auto-no-CF;
  * mesh2k_proxy — the 2K mesh-tangling geometry (5 convs/block) at
    reduced resolution under the 2-D H x W spatial decomposition;
  * mesh16_proxy — the 16x16-mesh decompositions at bench scale (batch 1,
    so sample parallelism is impossible): the solved plan mixes
    CF x spatial layers (CF collective + halo in one shard_map) and
    H split over the *product* of both mesh axes (core.halo), vs the
    uniform H x W baseline.
  * mesh2k_unreachable — the paper's §VI Table-2 memory story: batch 1
    under a synthetic per-device capacity limit that the sample-parallel
    (= replicated) plan cannot fit but the memory-aware solve
    (plan_line mem_limit=) does; both execute, and the solved plan's
    XLA-measured peak cross-checks the memory model.
  * overlap — the §IV-A latency-hiding A/B on ONE plan: the uniform
    H-split plan runs overlap-on (interior/boundary split, pinned halo
    issue order) vs force-serialized (loss_fn overlap=False: halo
    concatenated before one full conv).  The gate enforces that the
    schedule the calibrated η recommends (overlapped when η clears
    channel_conv.ETA_CHUNK_THRESHOLD, serialized below it) never
    measures slower than the rejected arm beyond tolerance — i.e. the
    calibration picks the measured winner of its own A/B.  The measured
    achieved-overlap η is emitted alongside the calibrated one.

A `ckpt_overhead` lane rides along (top-level report key): the same
compiled step runs bare vs with an async CheckpointManager.save enqueued
per call, and the gate fails when the save stalls the step beyond
--ckpt-tol — asynchronous checkpointing must stay off the critical path
(the fault-tolerance lever the elastic runtime depends on).

With --attribute the mesh16cf and mesh16_proxy auto plans additionally run
the segmented per-layer profiler (core.trace.trace_plan) and the
predicted-vs-measured join (plan.attribution_report): the workloads' known
single-digit model/measured end-to-end gap is decomposed into named
per-term drift ({fp,bp}_compute/{fp,bp}_comm/bpa/shuffle), written to
BENCH_attribution.json with the worst-drifting term named per workload.
Per-term drift beyond 5x prints an `# ATTRIBUTION WARNING` without
failing the exit code (the drift is a model-fidelity signal, not an
ordering-promise violation).

Output is both the legacy `name,us_per_call,derived` CSV rows and a
machine-readable BENCH_strategy.json: per-workload measured/predicted step
times AND peak memory (model-predicted vs XLA memory_analysis measured, so
the bench trajectory tracks memory alongside time), the auto-vs-uniform
measured ratio (the optimizer's ordering promise), and calibrated-vs-
analytic solver agreement (does the measured table change the solved plan,
and by how much the predicted cost).  With --gate the exit code enforces
the ordering promise — the CI bench lane fails when a solved auto plan
measures slower than uniform anywhere — and the capacity promise: a
mesh2k_unreachable memory-aware solve that fails (the solver cannot fit
its limit anymore) fails the gate too.  The capacity workload is exempt
from the ordering gate: its baseline is infeasible under the limit, so
beating it in time is not part of the promise.
"""
import os
import sys

if __name__ == "__main__":
    # the positional device count must come first: it is consumed before
    # jax import (XLA fixes the host device count at backend init)
    _n = int(sys.argv[1]) if len(sys.argv) > 1 and sys.argv[1].isdigit() \
        else 4
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from benchmarks._timing import interleaved_samples, percentile  # noqa: E402

SCHEMA = "repro/bench_strategy@1"


def _uniform_plan(plan_lib, sh, names, specs, mesh, machine, table):
    """A uniform plan costed through the same §V-B model for comparability."""
    uniform = plan_lib.NetworkPlan.uniform(sh, names)
    return dataclasses.replace(
        uniform, predicted=plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(sh) for n in names},
            specs, mesh, machine=machine, table=table).predicted)


def _measure_plans(cfg, batch, specs, plans, mesh, reps, rounds=4):
    """Measured seconds/step for every plan of one workload: compile and
    warm each train step, then hand the competing steps to the shared
    interleaved comparator (benchmarks/_timing.interleaved_min) so the
    auto-vs-uniform ratio is robust to host-load drift.  Each step is
    AOT-compiled so its XLA memory_analysis peak rides along.  A plan may
    be a (tag, plan) pair or a (tag, plan, overlap) triple — the overlap
    flag (default True) threads to meshnet.loss_fn, which is how the
    `overlap` workload force-serializes one arm of its A/B.  Returns
    ({tag: seconds}, {tag: measured peak bytes}, {tag: per-round means})
    — the point estimate is min-over-round-means as always; the raw round
    samples ride along so callers can report the p50/p95 spread."""
    import functools
    from repro.core.calibrate import compiled_peak_bytes
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in synthetic_mesh_batch(
        0, batch, cfg.input_hw, cfg.in_channels,
        out_hw=cfg.out_hw).items()}
    first = specs[0]
    lbl_spec = P("data") if batch % dict(mesh.shape)["data"] == 0 else P(None)
    with mesh:
        steps, peaks = {}, {}
        for entry in plans:
            tag, plan = entry[0], entry[1]
            ov = entry[2] if len(entry) > 2 else True
            spec = plan.input_spec(first.name, first.h, first.w, first.k,
                                   first.s, mesh)
            bb = {"image": jax.device_put(b["image"],
                                          NamedSharding(mesh, spec)),
                  "label": jax.device_put(b["label"],
                                          NamedSharding(mesh, lbl_spec))}
            step = jax.jit(jax.value_and_grad(
                lambda p, x, plan=plan, ov=ov: meshnet.loss_fn(
                    p, x, cfg, plan, mesh, overlap=ov)))
            compiled = step.lower(params, bb).compile()    # AOT: peak + call
            peaks[tag] = compiled_peak_bytes(compiled)
            compiled(params, bb)[0].block_until_ready()    # warm
            steps[tag] = functools.partial(compiled, params, bb)
        samples = interleaved_samples(steps, reps=reps, rounds=rounds)
        return {t: min(s) for t, s in samples.items()}, peaks, samples


def _analytic_view(machine, table):
    """The pre-composition cost model: the composition calibration factors
    reset to 1.0 and the shuffle:/composed: key families dropped from the
    table.  The local-conv entries stay — both views share them (they
    predate the composed calibration; the A/B isolates what composition
    calibration bought, not what conv timing bought)."""
    from repro.core.perfmodel import EmpiricalTable
    m = dataclasses.replace(machine, composed_cf_factor=1.0,
                            composed_halo_factor=1.0, shuffle_factor=1.0)
    t = EmpiricalTable({k: v for k, v in table.entries.items()
                        if not str(k[0]).startswith(("shuffle", "composed"))})
    return m, t


def _ratio_views(plan_lib, plan, specs, mesh, machine, table, measured_s):
    """Re-price the SAME measured plan through the analytic (factor-free,
    shuffle-table-free) view and report both model/measured ratios.  The
    `calibration_improves` bit is the tentpole's win condition: the
    composed-calibrated prediction must sit closer to the measurement
    (in log distance — over- and under-prediction count alike)."""
    m_a, t_a = _analytic_view(machine, table)
    pred_ana = plan_lib.compile_plan(
        {n: lp.dist for n, lp in plan.layers.items()}, specs, mesh,
        machine=m_a, table=t_a).predicted["total"]
    pred_cal = plan.predicted["total"]
    r_cal = float(pred_cal / measured_s)
    r_ana = float(pred_ana / measured_s)
    return {"ratio_calibrated": r_cal, "ratio_analytic": r_ana,
            "analytic_predicted_s": float(pred_ana),
            "calibrated_predicted_s": float(pred_cal),
            "calibration_improves":
                bool(abs(math.log(r_cal)) <= abs(math.log(r_ana)))}


def _solver_agreement(plan_lib, machine, table, specs, mesh, **kw):
    """Does solving on the measured table change the plan vs the analytic
    model, and by how much the predicted cost?  (The calibrated and the
    analytic solver must both return executable plans — this runs both.)"""
    auto_cal = plan_lib.plan_line(machine, specs, mesh, table=table, **kw)
    auto_ana = plan_lib.plan_line(machine, specs, mesh, **kw)
    differ = [n for n in auto_cal.layers
              if not auto_cal.layers[n].dist.same_as(auto_ana.layers[n].dist)]
    return auto_cal, {
        "calibrated_predicted_s": auto_cal.predicted["total"],
        "analytic_predicted_s": auto_ana.predicted["total"],
        "n_layers_differ": len(differ),
        "layers_differ": differ,
        "same_plan": not differ,
    }


def _bench_workload(name, cfg, batch, specs, plans, mesh, reps, rounds,
                    baseline_tag, auto_tag, agreement):
    measured, peaks, samples = _measure_plans(cfg, batch, specs, plans,
                                              mesh, reps, rounds)
    entries = {}
    for entry in plans:
        tag, plan = entry[0], entry[1]
        dt = measured[tag]
        p50 = percentile(samples[tag], 50)
        p95 = percentile(samples[tag], 95)
        pred = plan.predicted["total"] if plan.predicted else float("nan")
        pmem = plan.predicted["memory"]["peak_bytes"] \
            if plan.predicted and "memory" in plan.predicted else float("nan")
        mmem = peaks[tag]
        entries[tag] = {"measured_s": dt, "predicted_s": pred,
                        "measured_p50_s": p50, "measured_p95_s": p95,
                        "model_measured_ratio": pred / dt,
                        "predicted_peak_bytes": pmem,
                        "measured_peak_bytes": mmem,
                        "mem_model_measured_ratio":
                            pmem / mmem if mmem else float("nan"),
                        "n_reshards": plan.n_reshards}
        print(f"strategy_exec/{name}/{tag},{dt*1e6:.1f},"
              f"p50_us={p50*1e6:.1f} p95_us={p95*1e6:.1f} "
              f"predicted_us={pred*1e6:.1f} "
              f"model_measured_ratio={pred/dt:.3f} "
              f"predicted_peak_bytes={pmem:.0f} "
              f"measured_peak_bytes={mmem:.0f} "
              f"reshards={plan.n_reshards}")
    ratio = entries[auto_tag]["measured_s"] / \
        entries[baseline_tag]["measured_s"]
    return {"baseline": baseline_tag, "auto": auto_tag, "entries": entries,
            "auto_vs_uniform_measured": ratio,
            "solver_agreement": agreement}


def _bench_ckpt_overhead(cfg, batch, specs, plan, mesh, reps, rounds, tol):
    """Async checkpointing must stay off the step critical path.  The same
    compiled train-ish step runs in two interleaved arms: bare, and with a
    CheckpointManager.save enqueued per call (host copy synchronous, npz
    write on the daemon thread).  The measured ratio gates the CI bench
    lane: an async save that stalls the step beyond `tol` is the classic
    checkpoint-stall regression the async path exists to prevent."""
    import functools
    import itertools
    import shutil
    import tempfile
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in synthetic_mesh_batch(
        0, batch, cfg.input_hw, cfg.in_channels,
        out_hw=cfg.out_hw).items()}
    first = specs[0]
    lbl_spec = P("data") if batch % dict(mesh.shape)["data"] == 0 else P(None)
    ckdir = tempfile.mkdtemp()
    try:
        with mesh:
            spec = plan.input_spec(first.name, first.h, first.w, first.k,
                                   first.s, mesh)
            bb = {"image": jax.device_put(b["image"],
                                          NamedSharding(mesh, spec)),
                  "label": jax.device_put(b["label"],
                                          NamedSharding(mesh, lbl_spec))}
            step = jax.jit(jax.value_and_grad(
                lambda p, x: meshnet.loss_fn(p, x, cfg, plan, mesh)))
            compiled = step.lower(params, bb).compile()
            compiled(params, bb)[0].block_until_ready()        # warm
            ck = CheckpointManager(ckdir, keep=2, async_save=True)
            counter = itertools.count()

            def with_save():
                out = compiled(params, bb)
                ck.save(next(counter), params, extra={"step": 0})
                return out
            samples = interleaved_samples(
                {"no_ckpt": functools.partial(compiled, params, bb),
                 "async_ckpt": with_save}, reps=reps, rounds=rounds)
            ck.wait()
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    no = min(samples["no_ckpt"])
    asy = min(samples["async_ckpt"])
    return {"no_ckpt_s": no, "async_ckpt_s": asy,
            "overhead_ratio": asy / no, "tolerance": tol,
            "ok": asy / no <= 1 + tol}


def _attribute(targets, mesh, out_path, reps, rounds):
    """--attribute: decompose each target's model-vs-measured gap into
    named per-term drift.  Runs the segmented per-layer profiler
    (core.trace.trace_plan) on the solved plan and joins it against the
    perf-model prediction (plan.attribution_report); the JSON written to
    `out_path` names the worst-drifting cost term per workload.  Returns
    (warned, {workload: attribution report}) — warned is whether any term
    drifted beyond 5x (warn-only — printed, not gated); the reports feed
    calibrate.refit_from_attribution so the drift drives recalibration."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.trace import format_attribution, trace_plan
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    report = {"schema": "repro/bench_attribution@1",
              "backend": jax.default_backend(),
              "mesh": dict(mesh.shape), "workloads": {}}
    warned = False
    for name, (cfg, batch, specs, plan) in targets.items():
        params = meshnet.init(jax.random.PRNGKey(0), cfg)
        b = {k: jnp.asarray(v) for k, v in synthetic_mesh_batch(
            0, batch, cfg.input_hw, cfg.in_channels,
            out_hw=cfg.out_hw).items()}
        first = specs[0]
        spec = plan.input_spec(first.name, first.h, first.w, first.k,
                               first.s, mesh)
        lbl = P("data") if batch % dict(mesh.shape)["data"] == 0 else P(None)
        bb = {"image": jax.device_put(b["image"], NamedSharding(mesh, spec)),
              "label": jax.device_put(b["label"], NamedSharding(mesh, lbl))}
        trace = trace_plan(plan, params, bb, cfg=cfg, mesh=mesh,
                           reps=reps, rounds=rounds)
        rep = plan.attribution_report(trace)
        print(f"# attribution/{name} (worst term: {rep['worst_term']}):")
        print(format_attribution(rep))
        report["workloads"][name] = {"trace": trace.to_dict(),
                                     "attribution": rep}
        for term, t in rep["terms"].items():
            if t["drift"] > 5.0 or t["drift"] < 0.2:
                warned = True
                print(f"# ATTRIBUTION WARNING: {name} term {term} drifts "
                      f"{t['drift']:.2f}x from the model (warn-only)")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {out_path}")
    return warned, {n: w["attribution"]
                    for n, w in report["workloads"].items()}


def run(args) -> int:
    from repro.core import calibrate as calib
    from repro.core import plan as plan_lib
    from repro.core.channel_conv import CFSharding
    from repro.core.spatial_conv import ConvSharding
    from repro.launch.mesh import make_mesh
    from repro.models.cnn import meshnet

    ndev = jax.device_count()
    # only a positional count the user actually passed can be "ignored"
    # (XLA_FLAGS set in the environment is honored as-is, no warning)
    if args.ndevices is not None and args.ndevices != ndev:
        print(f"# WARNING: requested {args.ndevices} devices but the "
              f"backend has {ndev} — the positional count only takes "
              f"effect as the FIRST argument (it must be consumed before "
              f"jax import) and is overridden by XLA_FLAGS in the "
              f"environment")
    data = max(1, ndev // 2)
    model = max(1, ndev // data)
    mesh = make_mesh(data=data, model=model)
    uni_sh = ConvSharding(batch_axes=("data",), h_axis="model")

    # --- workloads: the ONE registry the static-analysis lane audits -----
    # (repro.analysis.workloads — keeping the configs there means the
    # plans this bench times are exactly the plans dryrun --audit proves)
    from repro.analysis.workloads import (CFG128 as cfg128, CFG16 as cfg16,
                                          CFG2K as cfg2k, CFG16P as cfg16p,
                                          CFG2KU as cfg2ku)
    specs128 = meshnet.layer_specs(cfg128, 2)
    specs16 = meshnet.layer_specs(cfg16, 2)
    specs2k = meshnet.layer_specs(cfg2k, 1)
    specs16p = meshnet.layer_specs(cfg16p, 1)
    specs2ku = meshnet.layer_specs(cfg2ku, 1)

    # --- calibrate the cost inputs on the live backend (§V, measured) ----
    # grow_table: a calibration restored from the CI cache (or a previous
    # local run) is extended with any shard shapes these workloads add,
    # instead of silently degrading to the analytic model for them
    union = list(specs128) + list(specs16) + \
        (list(specs2k) + list(specs16p) + list(specs2ku)
         if data > 1 else [])
    cal = calib.load_or_run(args.calibration, union, mesh, reps=args.reps,
                            grow_table=True)
    machine, table = cal.machine, cal.table

    workloads = {}
    attr_targets = {}     # --attribute: {workload: (cfg, batch, specs, plan)}
    audit_targets = {}    # --audit: {workload: (plan, specs, cfg)}

    # --- mesh128: the strategy choice is non-trivial on this mesh --------
    # (batch 2 < device count: pure sample parallelism invalid)
    names = meshnet.layer_names(cfg128)
    auto, agree = _solver_agreement(plan_lib, machine, table, specs128, mesh)
    uni128 = _uniform_plan(plan_lib, uni_sh, names, specs128, mesh,
                           machine, table)
    workloads["mesh128"] = _bench_workload(
        "mesh128", cfg128, 2, specs128,
        (("uniform", uni128), ("auto", auto)),
        mesh, args.reps, args.rounds, "uniform", "auto", agree)
    audit_targets["mesh128"] = (auto, specs128, cfg128)

    # --- overlap: the §IV-A latency-hiding A/B on the SAME plan ----------
    # one uniform H-split plan, two arms: overlap=True (interior/boundary
    # split + pinned halo issue order) vs overlap=False (halo concatenated
    # before one full-tile conv — nothing to hide).  The gate checks the
    # calibration's CALL, not a fixed winner: the arm the fitted η
    # recommends (overlap pays iff η clears the same threshold that
    # enables CF chunking) must not measure slower than the rejected arm
    # beyond tolerance.  On hardware whose scheduler genuinely hides the
    # halo (high η) that means overlapped <= serialized; on a machine
    # that cannot hide it (low η — host XLA) it means the split's
    # overhead stays on the serialized side of tolerance.  Either way a
    # calibration that mispredicts its own A/B fails the lane.  The
    # measured achieved η rides into the report next to the calibrated
    # one so the trajectory can watch them drift.
    from repro.core.channel_conv import ETA_CHUNK_THRESHOLD
    names = meshnet.layer_names(cfg128)
    ov_plan = _uniform_plan(plan_lib, uni_sh, names, specs128, mesh,
                            machine, table)
    ser_plan = dataclasses.replace(
        ov_plan, predicted=plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(uni_sh) for n in names},
            specs128, mesh, machine=machine, table=table,
            overlap=False).predicted)
    overlap_pays = machine.overlap_eta >= ETA_CHUNK_THRESHOLD
    chosen, rejected = ("overlapped", "serialized") if overlap_pays \
        else ("serialized", "overlapped")
    workloads["overlap"] = _bench_workload(
        "overlap", cfg128, 2, specs128,
        (("serialized", ser_plan, False), ("overlapped", ov_plan, True)),
        mesh, args.reps, args.rounds, rejected, chosen,
        {"same_plan": True, "n_layers_differ": 0, "layers_differ": [],
         "note": "same plan both arms; the A/B toggles overlap only"})
    workloads["overlap"]["calibrated_choice"] = chosen
    audit_targets["overlap"] = (ov_plan, specs128, cfg128)
    t_ov = workloads["overlap"]["entries"]["overlapped"]["measured_s"]
    t_ser = workloads["overlap"]["entries"]["serialized"]["measured_s"]
    credit = sum(ov_plan.predicted.get("overlap_credit", {}).values())
    eta_cal = machine.overlap_eta
    hidden_at_1 = credit / eta_cal if eta_cal > 0 else 0.0
    eta_meas = min(max((t_ser - t_ov) / hidden_at_1, 0.0), 1.0) \
        if hidden_at_1 > 0 else None
    workloads["overlap"]["eta"] = {
        "calibrated": eta_cal,
        "measured": eta_meas,
        "predicted_hidden_s": credit,
        "measured_hidden_s": t_ser - t_ov,
    }
    print(f"# overlap: serialized {t_ser*1e6:.1f}us, overlapped "
          f"{t_ov*1e6:.1f}us; eta calibrated {eta_cal:.2f}, measured "
          + (f"{eta_meas:.2f}" if eta_meas is not None else "n/a"))

    # --- mesh16cf: late layers too small to split spatially (h=4 < k) but
    # channel-heavy — the §III-D sweet spot.  The auto plan should contain
    # CF layers; its model_measured_ratio cross-checks the CF cost terms
    # against the core.channel_conv runtime. -----------------------------
    names = meshnet.layer_names(cfg16)
    auto_cf, agree = _solver_agreement(plan_lib, machine, table, specs16,
                                       mesh)
    n_cf = sum(isinstance(lp.sharding, CFSharding)
               for lp in auto_cf.layers.values())
    print(f"# mesh16cf auto plan: {n_cf} CF layers")
    wide16 = plan_lib.plan_line(machine, specs16, mesh, table=table,
                                search=args.search)
    workloads["mesh16cf"] = _bench_workload(
        "mesh16cf", cfg16, 2, specs16,
        (("uniform", _uniform_plan(plan_lib, uni_sh, names, specs16, mesh,
                                   machine, table)),
         ("auto_cf", auto_cf),
         ("auto_wide", wide16),
         ("auto_nocf", plan_lib.plan_line(machine, specs16, mesh,
                                          table=table,
                                          allow_channel_filter=False))),
        mesh, args.reps, args.rounds, "uniform", "auto_cf", agree)
    workloads["mesh16cf"]["n_cf_layers"] = n_cf
    workloads["mesh16cf"]["ratio_views"] = _ratio_views(
        plan_lib, auto_cf, specs16, mesh, machine, table,
        workloads["mesh16cf"]["entries"]["auto_cf"]["measured_s"])
    attr_targets["mesh16cf"] = (cfg16, 2, specs16, auto_cf)
    audit_targets["mesh16cf"] = (auto_cf, specs16, cfg16)
    audit_targets["mesh16cf_wide"] = (wide16, specs16, cfg16)

    # --- mesh2k_proxy: the 2K model's depth (5 convs/block) at reduced
    # resolution, under the 2-D H x W decomposition (W on the data axis,
    # H on the model axis; batch 1 — the paper's memory-bound regime). ----
    if data > 1:
        names = meshnet.layer_names(cfg2k)
        hw_sh = ConvSharding(batch_axes=(), h_axis="model", w_axis="data")
        auto, agree = _solver_agreement(plan_lib, machine, table, specs2k,
                                        mesh)
        workloads["mesh2k_proxy"] = _bench_workload(
            "mesh2k_proxy", cfg2k, 1, specs2k,
            (("hxw", _uniform_plan(plan_lib, hw_sh, names, specs2k, mesh,
                                   machine, table)),
             ("auto", auto)),
            mesh, args.reps, args.rounds, "hxw", "auto", agree)
        audit_targets["mesh2k_proxy"] = (auto, specs2k, cfg2k)

    # --- mesh16_proxy: the 16x16-mesh decompositions at bench scale.
    # Batch 1 rules out sample parallelism, so the solver composes: CF on
    # one axis with H on the other (one shard_map: halo + CF collective)
    # and H over the *product* of both axes where channels are thin.  The
    # auto plan must hold the ordering promise against uniform H x W. ----
    if data > 1:
        names = meshnet.layer_names(cfg16p)
        hw_sh = ConvSharding(batch_axes=(), h_axis="model", w_axis="data")
        auto, agree = _solver_agreement(plan_lib, machine, table, specs16p,
                                        mesh)
        n_cfsp = sum(isinstance(lp.sharding, CFSharding)
                     and lp.sharding.is_spatial
                     for lp in auto.layers.values())
        n_multi = sum(len(lp.sharding.h_axes) > 1
                      or len(lp.sharding.w_axes) > 1
                      for lp in auto.layers.values())
        print(f"# mesh16_proxy auto plan: {n_cfsp} CF x spatial layers, "
              f"{n_multi} product-axis spatial layers")
        wide16p = plan_lib.plan_line(machine, specs16p, mesh, table=table,
                                     search=args.search)
        workloads["mesh16_proxy"] = _bench_workload(
            "mesh16_proxy", cfg16p, 1, specs16p,
            (("uniform", _uniform_plan(plan_lib, hw_sh, names, specs16p,
                                       mesh, machine, table)),
             ("auto", auto),
             ("auto_wide", wide16p)),
            mesh, args.reps, args.rounds, "uniform", "auto", agree)
        workloads["mesh16_proxy"]["n_cf_spatial_layers"] = n_cfsp
        workloads["mesh16_proxy"]["n_product_axis_layers"] = n_multi
        workloads["mesh16_proxy"]["ratio_views"] = _ratio_views(
            plan_lib, auto, specs16p, mesh, machine, table,
            workloads["mesh16_proxy"]["entries"]["auto"]["measured_s"])
        attr_targets["mesh16_proxy"] = (cfg16p, 1, specs16p, auto)
        audit_targets["mesh16_proxy"] = (auto, specs16p, cfg16p)
        audit_targets["mesh16_proxy_wide"] = (wide16p, specs16p, cfg16p)

    # --- mesh2k_unreachable: the paper's Table-2 memory story as an
    # executable benchmark.  Batch 1: sample parallelism cannot reduce
    # per-device memory below one full sample, so the 'sample-parallel'
    # uniform plan is the replicated one.  A synthetic capacity limit is
    # set between the replicated peak and what the spatial decompositions
    # reach — the memory-aware solve (plan_line mem_limit=) must return a
    # plan that fits AND executes, while uniform sample-parallel is
    # infeasible under the limit.  Its measured XLA peak cross-checks the
    # §VI memory model on a real compiled step. -------------------------
    mem_failures = []
    if data > 1:
        namesu = meshnet.layer_names(cfg2ku)
        rep_plan = _uniform_plan(plan_lib, ConvSharding(), namesu, specs2ku,
                                 mesh, machine, table)
        rep_peak = rep_plan.predicted["memory"]["peak_bytes"]
        limit = 0.5 * rep_peak
        try:
            auto_u, agree = _solver_agreement(plan_lib, machine, table,
                                              specs2ku, mesh,
                                              mem_limit=limit)
        except Exception as e:
            auto_u = None
            mem_failures.append(
                f"mesh2k_unreachable: memory-aware solve failed under "
                f"limit {limit:.0f}B: {e}")
        if auto_u is not None:
            # plan_line already validated the fit (it raises into the
            # except-branch above when the solve stops fitting — THAT is
            # the "stops fitting" gate); the limit is derived from the
            # uniform peak, so uniform is infeasible by construction.
            # Peaks are recorded so the bench trajectory tracks them.
            auto_peak = auto_u.predicted["memory"]["peak_bytes"]
            workloads["mesh2k_unreachable"] = _bench_workload(
                "mesh2k_unreachable", cfg2ku, 1, specs2ku,
                (("uniform_sample", rep_plan), ("auto_memfit", auto_u)),
                mesh, args.reps, args.rounds, "uniform_sample",
                "auto_memfit", agree)
            workloads["mesh2k_unreachable"]["mem"] = {
                "limit_bytes": limit,
                "uniform_peak_bytes": rep_peak,
                "auto_peak_bytes": auto_peak,
            }
            print(f"# mesh2k_unreachable: limit {limit:.0f}B, uniform "
                  f"{rep_peak:.0f}B (DOES NOT FIT), "
                  f"auto {auto_peak:.0f}B (fits)")
            audit_targets["mesh2k_unreachable"] = (auto_u, specs2ku, cfg2ku)

    # --- ckpt_overhead: async save must stay off the critical path -------
    # (top-level report key, NOT a workload: the ordering gate below
    # iterates workloads and this lane has its own tolerance)
    ckpt_overhead = _bench_ckpt_overhead(cfg128, 2, specs128, uni128, mesh,
                                         args.reps, args.rounds,
                                         args.ckpt_tol)
    print(f"# ckpt_overhead: no_ckpt "
          f"{ckpt_overhead['no_ckpt_s']*1e6:.1f}us, async_ckpt "
          f"{ckpt_overhead['async_ckpt_s']*1e6:.1f}us, ratio "
          f"{ckpt_overhead['overhead_ratio']:.3f} "
          f"(tol {1 + args.ckpt_tol:.2f}x)")

    # --- --audit: static collective audit of the measured plans ----------
    # (recorded per workload, NOT gated here — the CI static lane gates;
    # this rides along so BENCH_strategy.json carries the findings next to
    # the timings they explain)
    if args.audit:
        from repro import analysis
        for name, (plan, specs, cfg) in audit_targets.items():
            findings = plan.audit(specs, mesh, cfg=cfg, overlap=True,
                                  hlo=False)
            errs = analysis.error_count(findings)
            print(f"# audit/{name}: {len(findings)} finding(s), "
                  f"{errs} error(s)")
            rec = {"n_findings": len(findings), "n_errors": errs,
                   "findings": [f.to_json() for f in findings]}
            if name in workloads:
                workloads[name]["audit"] = rec
            else:
                # widened-search plans audit under their parent workload
                # ("mesh16cf_wide" -> mesh16cf["audit_wide"]) — the
                # widened solver must stay as auditable as the greedy one
                workloads[name.rsplit("_wide", 1)[0]]["audit_wide"] = rec

    # --- the gate: the optimizer's ordering promise ----------------------
    tol = args.gate_tol
    # the ordering promise applies where the baseline was a *feasible*
    # alternative; the capacity workload's baseline is infeasible under
    # its limit by construction, so only its fit ("mem" key) gates
    failures = [
        f"{name}: {wl['auto']} "
        f"{wl['entries'][wl['auto']]['measured_s']*1e6:.1f}us"
        f" > {1 + tol:.2f}x {wl['baseline']} "
        f"{wl['entries'][wl['baseline']]['measured_s']*1e6:.1f}us"
        for name, wl in workloads.items()
        if "mem" not in wl and wl["auto_vs_uniform_measured"] > 1 + tol]
    failures += mem_failures          # capacity promises gate too
    if not ckpt_overhead["ok"]:
        failures.append(
            f"ckpt_overhead: async save slows the step "
            f"{ckpt_overhead['overhead_ratio']:.2f}x "
            f"(> {1 + args.ckpt_tol:.2f}x) — checkpoint stall on the "
            f"critical path")

    # --- the widened-search promise: the wider candidate space + global
    # search must MEASURE no slower than greedy somewhere (the wide set is
    # a superset of the narrow one, so the predicted cost can only drop;
    # this gate checks the measurement backs the prediction on at least
    # one workload — gated like the ordering promise, same tolerance) ----
    search_cmp = {}
    for name, wl in workloads.items():
        e = wl["entries"]
        if "auto_wide" not in e:
            continue
        greedy_tag = wl["auto"]
        r = e["auto_wide"]["measured_s"] / e[greedy_tag]["measured_s"]
        search_cmp[name] = {
            "mode": args.search,
            "greedy_measured_s": e[greedy_tag]["measured_s"],
            "wide_measured_s": e["auto_wide"]["measured_s"],
            "greedy_predicted_s": e[greedy_tag]["predicted_s"],
            "wide_predicted_s": e["auto_wide"]["predicted_s"],
            "wide_vs_greedy_measured": r,
        }
        wl["search"] = search_cmp[name]
        print(f"# search/{name}: wide({args.search})/greedy measured "
              f"{r:.3f}, predicted "
              f"{e['auto_wide']['predicted_s']*1e6:.1f}us vs "
              f"{e[greedy_tag]['predicted_s']*1e6:.1f}us")
    if search_cmp:
        best = min(s["wide_vs_greedy_measured"] for s in search_cmp.values())
        if best > 1 + tol:
            failures.append(
                f"search: widened search ({args.search}) measured slower "
                f"than greedy on every workload (best wide/greedy "
                f"{best:.3f} > {1 + tol:.2f}) — the wider strategy space "
                f"must pay somewhere")

    # --- the model-fidelity gate: the composed calibration's headline ----
    # (ISSUE win condition: the calibrated model/measured ratio on the
    # composition-heavy workloads must sit within --ratio-tol of 1.0,
    # either side; --ratio-warn-only downgrades a miss to a warning so
    # the first CI run records the baseline before the gate flips on)
    ratio_gate = {"tolerance": args.ratio_tol,
                  "warn_only": bool(args.ratio_warn_only), "checks": {}}
    for name in ("mesh16cf", "mesh16_proxy"):
        rv = workloads.get(name, {}).get("ratio_views")
        if not rv:
            continue
        r = rv["ratio_calibrated"]
        off = float(max(r, 1 / r)) if r > 0 else float("inf")
        ok = bool(off <= args.ratio_tol)
        ratio_gate["checks"][name] = dict(rv, off_by=off, ok=ok)
        print(f"# ratio/{name}: calibrated {r:.3f} "
              f"(off {off:.2f}x, tol {args.ratio_tol:.1f}x), analytic "
              f"{rv['ratio_analytic']:.3f}, "
              f"calibration_improves={rv['calibration_improves']}")
        if not ok:
            msg = (f"ratio: {name} calibrated model/measured {r:.3f} is "
                   f"off by {off:.2f}x > --ratio-tol "
                   f"{args.ratio_tol:.1f}x")
            if args.ratio_warn_only:
                print(f"# RATIO WARNING (warn-only): {msg}")
            else:
                failures.append(msg)

    # --- --attribute + refit: measured drift drives recalibration --------
    # (before the report write so the refit outcome rides along in it)
    attribution_refit = {}
    if args.attribute:
        _, attr_reps = _attribute(attr_targets, mesh, args.attribution_out,
                                  args.reps, args.rounds)
        for name, rep in attr_reps.items():
            changed = calib.refit_from_attribution(
                cal, rep, path=args.calibration, damp=0.5)
            if changed:
                attribution_refit[name] = changed
                print(f"# refit/{name}: " + ", ".join(
                    f"{k}={v:.3f}" for k, v in sorted(changed.items())))

    report = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "ndevices": ndev,
        "mesh": dict(mesh.shape),
        "reps": args.reps,
        "rounds": args.rounds,
        "calibration": {"path": args.calibration,
                        "machine": dataclasses.asdict(machine),
                        "table_entries": len(table)},
        "workloads": workloads,
        "ckpt_overhead": ckpt_overhead,
        "search": search_cmp,
        "ratio_gate": ratio_gate,
        "attribution_refit": attribution_refit,
        "gate": {"enabled": bool(args.gate), "tolerance": tol,
                 "ok": not failures, "failures": failures},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out}")
    for name, wl in workloads.items():
        print(f"# {name}: auto/uniform measured "
              f"{wl['auto_vs_uniform_measured']:.3f}, solver agreement "
              f"{'same plan' if wl['solver_agreement']['same_plan'] else str(wl['solver_agreement']['n_layers_differ']) + ' layers differ'}")
    if failures:
        print("# GATE FAILURES (solved plan measured slower than its "
              "baseline):")
        for x in failures:
            print(f"#   {x}")
        return 1 if args.gate else 0
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("ndevices", nargs="?", type=int, default=None,
                    help="host CPU device count (must be first arg; read "
                         "before jax import to set XLA_FLAGS; default 4)")
    ap.add_argument("--out", default="BENCH_strategy.json")
    ap.add_argument("--calibration", default="BENCH_calibration.json",
                    help="calibration JSON: loaded when present, else "
                         "measured over the bench workloads and written")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed calls per round")
    ap.add_argument("--rounds", type=int, default=4,
                    help="interleaved measurement rounds per workload (the "
                         "per-plan time is the min over per-round means)")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero when a solved auto plan measures "
                         "slower than the uniform baseline (the CI lane's "
                         "perf-trajectory gate)")
    ap.add_argument("--gate-tol", type=float, default=0.10,
                    help="noise tolerance for the gate: fail only when "
                         "auto > (1+tol) * uniform measured")
    ap.add_argument("--ckpt-tol", type=float, default=0.5,
                    help="tolerance for the checkpoint-overhead lane: fail "
                         "when the async-save arm is slower than the bare "
                         "step beyond (1+tol)x — the save must overlap, "
                         "not stall")
    ap.add_argument("--attribute", action="store_true",
                    help="segmented per-layer profiling of the mesh16cf/"
                         "mesh16_proxy auto plans (core.trace.trace_plan): "
                         "decompose the model-vs-measured gap into named "
                         "per-term drift and write --attribution-out; "
                         "drift beyond 5x warns without failing")
    ap.add_argument("--attribution-out", default="BENCH_attribution.json")
    ap.add_argument("--search", default="beam:4",
                    metavar="beam[:N]|hillclimb|greedy",
                    help="search mode for the widened-search arm "
                         "(auto_wide) on mesh16cf/mesh16_proxy: wide "
                         "candidate set + this solver, A/B'd against the "
                         "greedy longest-path-first solve and gated like "
                         "the ordering promise")
    ap.add_argument("--ratio-tol", type=float, default=10.0,
                    help="model-fidelity gate: fail when the calibrated "
                         "model/measured ratio on mesh16cf/mesh16_proxy "
                         "is off from 1.0 by more than this factor "
                         "(either side)")
    ap.add_argument("--ratio-warn-only", action="store_true",
                    help="downgrade --ratio-tol misses to warnings (for "
                         "the first CI run that records the baseline "
                         "before the gate flips on)")
    ap.add_argument("--audit", action="store_true",
                    help="run the static collective auditor "
                         "(repro.analysis) on every measured auto plan "
                         "and record the findings per workload in the "
                         "report JSON — lowering-only, never gates here "
                         "(the CI static lane gates)")
    args = ap.parse_args(argv)
    from repro.core.strategy import parse_search
    try:
        parse_search(args.search)
    except ValueError as e:
        ap.error(str(e))
    return run(args)


if __name__ == "__main__":
    sys.exit(main())
