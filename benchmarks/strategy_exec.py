"""Uniform vs solved per-layer plans: measured step time cross-checked
against the §V perf model — the validation loop the paper closes with
(predicted vs measured, Table I-III).

  PYTHONPATH=src python -m benchmarks.strategy_exec [ndevices]

Runs on `ndevices` host CPU devices (default 4, set before jax import).
For each CNN workload it times a jitted loss+grad step under

  * the legacy uniform hybrid plan (one ConvSharding everywhere), and
  * the §V-C solved auto plan (per-layer dists + reshard points),

and prints `name,us_per_call,derived` CSV rows carrying the perf-model
prediction from a host-calibrated Machine.  The absolute model/measured
ratio calibrates the Machine constants; the *relative* ordering
(auto <= uniform) is the optimizer's promise.
"""
import os
import sys

if __name__ == "__main__":
    _n = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}")

import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def _time_step(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def _host_machine():
    """Calibrate a perf-model Machine to this host: measure achieved conv
    flops once, use loopback-ish comm constants (shared memory)."""
    from repro.core.perfmodel import Machine
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 64)) * 0.1
    f = jax.jit(lambda x, w: jax.lax.conv_general_dilated(
        x, w, (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    f(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        y = f(x, w)
    y.block_until_ready()
    dt = (time.perf_counter() - t0) / 10
    flops = 2.0 * 4 * 32 * 64 * 64 * 9 * 64
    return Machine("host-cpu", peak_flops=flops / dt, mem_bw=20e9,
                   alpha=5e-6, beta=1 / 10.0e9,
                   alpha_coll=8e-6, beta_coll=1 / 10.0e9, wordsize=4,
                   compute_efficiency=1.0)


def run() -> None:
    from repro.core import plan as plan_lib
    from repro.core.spatial_conv import ConvSharding
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.launch.mesh import make_mesh
    from repro.models.cnn import meshnet

    ndev = jax.device_count()
    data = max(1, ndev // 2)
    model = max(1, ndev // data)
    mesh = make_mesh(data=data, model=model)
    machine = _host_machine()

    # a meshnet whose geometry makes the strategy choice non-trivial on
    # this mesh (batch 2 < device count: pure sample parallelism invalid)
    cfg = meshnet.MeshNetConfig("bench", input_hw=128, in_channels=8,
                                convs_per_block=2, widths=(16, 32, 32),
                                bn_scope="global")
    batch = 2
    specs = meshnet.layer_specs(cfg, batch)
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in synthetic_mesh_batch(
        0, batch, cfg.input_hw, cfg.in_channels,
        out_hw=cfg.out_hw).items()}

    uni_sh = ConvSharding(batch_axes=("data",), h_axis="model")
    names = meshnet.layer_names(cfg)
    uniform = plan_lib.NetworkPlan.uniform(uni_sh, names)
    # cost the uniform plan through the same §V-B model for comparability
    uniform = dataclasses.replace(
        uniform, predicted=plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(uni_sh) for n in names},
            specs, mesh, machine=machine).predicted)
    auto = plan_lib.plan_line(machine, specs, mesh)

    for tag, plan in (("uniform", uniform), ("auto", auto)):
        def put(v):
            first = specs[0]
            spec = plan.input_spec(first.name, first.h, first.w,
                                   first.k, first.s, mesh)
            return jax.device_put(v, NamedSharding(mesh, spec))

        bb = {"image": put(b["image"]),
              "label": jax.device_put(b["label"],
                                      NamedSharding(mesh, P("data")))}
        with mesh:
            step = jax.jit(jax.value_and_grad(
                lambda p, x: meshnet.loss_fn(p, x, cfg, plan, mesh)))
            dt = _time_step(lambda p, x: step(p, x), params, bb)
        pred = plan.predicted["total"] if plan.predicted else float("nan")
        print(f"strategy_exec/mesh128/{tag},{dt*1e6:.1f},"
              f"predicted_us={pred*1e6:.1f} "
              f"model_measured_ratio={pred/dt:.3f} "
              f"reshards={plan.n_reshards}")


if __name__ == "__main__":
    run()
