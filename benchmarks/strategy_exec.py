"""Uniform vs solved per-layer plans: measured step time cross-checked
against the §V perf model — the validation loop the paper closes with
(predicted vs measured, Table I-III).

  PYTHONPATH=src python -m benchmarks.strategy_exec [ndevices]

Runs on `ndevices` host CPU devices (default 4, set before jax import).
Three workloads:

  * mesh128 — the strategy-choice workload from PR 1: uniform hybrid vs
    the §V-C solved auto plan (per-layer dists + reshard points);
  * mesh16cf — a small-spatial, channel-heavy meshnet where the solver
    picks §III-D channel/filter layers: cross-checks the perf model's CF
    cost terms (reduce-scatter fwd, all-gather BPw) against the
    core.channel_conv runtime, and A/Bs auto-with-CF vs auto-no-CF;
  * mesh2k_proxy — the 2K mesh-tangling geometry (5 convs/block) at
    reduced resolution under the 2-D H x W spatial decomposition, the
    ROADMAP item on exercising W-axis splits.

Each prints `name,us_per_call,derived` CSV rows carrying the perf-model
prediction from a host-calibrated Machine.  The absolute model/measured
ratio calibrates the Machine constants; the *relative* ordering
(auto <= uniform) is the optimizer's promise.
"""
import os
import sys

if __name__ == "__main__":
    _n = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}")

import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def _time_step(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def _host_machine():
    """Calibrate a perf-model Machine to this host: measure achieved conv
    flops once, use loopback-ish comm constants (shared memory)."""
    from repro.core.perfmodel import Machine
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 64)) * 0.1
    f = jax.jit(lambda x, w: jax.lax.conv_general_dilated(
        x, w, (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    f(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        y = f(x, w)
    y.block_until_ready()
    dt = (time.perf_counter() - t0) / 10
    flops = 2.0 * 4 * 32 * 64 * 64 * 9 * 64
    return Machine("host-cpu", peak_flops=flops / dt, mem_bw=20e9,
                   alpha=5e-6, beta=1 / 10.0e9,
                   alpha_coll=8e-6, beta_coll=1 / 10.0e9, wordsize=4,
                   compute_efficiency=1.0)


def _uniform_plan(plan_lib, sh, names, specs, mesh, machine):
    """A uniform plan costed through the same §V-B model for comparability."""
    uniform = plan_lib.NetworkPlan.uniform(sh, names)
    return dataclasses.replace(
        uniform, predicted=plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(sh) for n in names},
            specs, mesh, machine=machine).predicted)


def _bench_plans(workload, cfg, batch, specs, plans, mesh) -> None:
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in synthetic_mesh_batch(
        0, batch, cfg.input_hw, cfg.in_channels,
        out_hw=cfg.out_hw).items()}
    for tag, plan in plans:
        def put(v):
            first = specs[0]
            spec = plan.input_spec(first.name, first.h, first.w,
                                   first.k, first.s, mesh)
            return jax.device_put(v, NamedSharding(mesh, spec))

        lbl_spec = P("data") if batch % dict(mesh.shape)["data"] == 0 \
            else P(None)
        bb = {"image": put(b["image"]),
              "label": jax.device_put(b["label"],
                                      NamedSharding(mesh, lbl_spec))}
        with mesh:
            step = jax.jit(jax.value_and_grad(
                lambda p, x: meshnet.loss_fn(p, x, cfg, plan, mesh)))
            dt = _time_step(lambda p, x: step(p, x), params, bb)
        pred = plan.predicted["total"] if plan.predicted else float("nan")
        print(f"strategy_exec/{workload}/{tag},{dt*1e6:.1f},"
              f"predicted_us={pred*1e6:.1f} "
              f"model_measured_ratio={pred/dt:.3f} "
              f"reshards={plan.n_reshards}")


def run() -> None:
    from repro.core import plan as plan_lib
    from repro.core.channel_conv import CFSharding
    from repro.core.spatial_conv import ConvSharding
    from repro.launch.mesh import make_mesh
    from repro.models.cnn import meshnet

    ndev = jax.device_count()
    data = max(1, ndev // 2)
    model = max(1, ndev // data)
    mesh = make_mesh(data=data, model=model)
    machine = _host_machine()
    uni_sh = ConvSharding(batch_axes=("data",), h_axis="model")

    # --- mesh128: the strategy choice is non-trivial on this mesh ---------
    # (batch 2 < device count: pure sample parallelism invalid)
    cfg = meshnet.MeshNetConfig("bench", input_hw=128, in_channels=8,
                                convs_per_block=2, widths=(16, 32, 32),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 2)
    names = meshnet.layer_names(cfg)
    _bench_plans("mesh128", cfg, 2, specs, (
        ("uniform", _uniform_plan(plan_lib, uni_sh, names, specs, mesh,
                                  machine)),
        ("auto", plan_lib.plan_line(machine, specs, mesh))), mesh)

    # --- mesh16cf: late layers too small to split spatially (h=4 < k) but
    # channel-heavy — the §III-D sweet spot.  The auto plan should contain
    # CF layers; its model_measured_ratio cross-checks the CF cost terms
    # against the core.channel_conv runtime. -----------------------------
    cfg = meshnet.MeshNetConfig("bench16", input_hw=16, in_channels=8,
                                convs_per_block=1, widths=(32, 64, 64),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 2)
    names = meshnet.layer_names(cfg)
    auto_cf = plan_lib.plan_line(machine, specs, mesh)
    n_cf = sum(isinstance(lp.sharding, CFSharding)
               for lp in auto_cf.layers.values())
    print(f"# mesh16cf auto plan: {n_cf} CF layers")
    _bench_plans("mesh16cf", cfg, 2, specs, (
        ("uniform", _uniform_plan(plan_lib, uni_sh, names, specs, mesh,
                                  machine)),
        ("auto_cf", auto_cf),
        ("auto_nocf", plan_lib.plan_line(machine, specs, mesh,
                                         allow_channel_filter=False))),
        mesh)

    # --- mesh2k_proxy: the 2K model's depth (5 convs/block) at reduced
    # resolution, under the 2-D H x W decomposition (W on the data axis,
    # H on the model axis; batch 1 — the paper's memory-bound regime). ----
    if data > 1:
        cfg = meshnet.MeshNetConfig("bench2k", input_hw=64, in_channels=8,
                                    convs_per_block=5, widths=(16, 32),
                                    bn_scope="global")
        specs = meshnet.layer_specs(cfg, 1)
        names = meshnet.layer_names(cfg)
        hw_sh = ConvSharding(batch_axes=(), h_axis="model", w_axis="data")
        _bench_plans("mesh2k_proxy", cfg, 1, specs, (
            ("hxw", _uniform_plan(plan_lib, hw_sh, names, specs, mesh,
                                  machine)),
            ("auto", plan_lib.plan_line(machine, specs, mesh))), mesh)


if __name__ == "__main__":
    run()
