"""Shared benchmark timing — warmup (absorbs jit compilation),
block_until_ready on every rep, trimmed-mean reduction.

Every benchmark in this directory times through this module so no script
grows its own ad-hoc loop again; the implementations live in `repro.utils`
so src-side code (the calibrator core.calibrate, the segmented profiler
core.trace) shares them without depending on `benchmarks`.
"""
from repro.utils import (interleaved_min, interleaved_samples,  # noqa: F401
                         percentile, time_fn, trimmed_mean)
