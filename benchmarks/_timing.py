"""Shared benchmark timing — warmup (absorbs jit compilation),
block_until_ready on every rep, trimmed-mean reduction.

Every benchmark in this directory times through this module so no script
grows its own ad-hoc loop again; the single-callable loop lives in
`repro.utils.time_fn` so src-side code (the calibrator, core.calibrate)
shares it without depending on `benchmarks`.
"""
import time

import jax

from repro.utils import time_fn, trimmed_mean  # noqa: F401


def interleaved_min(fns, reps: int = 5, rounds: int = 4):
    """Comparative wall-clock for competing callables: {tag: seconds/call}.

    Candidates are timed in alternating rounds (A, B, A, B, ...) so
    machine-load drift during the run hits every candidate equally —
    timing each in one contiguous block makes their ratio track whatever
    else the host was doing rather than the candidates (observed 40%
    swings between *identical* programs).  The per-tag estimate is the
    minimum over per-round means: the noise-floor round is the one where
    the host interfered least, and it is the comparable number across
    candidates.  Callables must already be compiled/warmed (call each once
    first) and take no arguments.
    """
    samples = {tag: [] for tag in fns}
    for _ in range(rounds):
        for tag, fn in fns.items():
            t0 = time.perf_counter()
            for _ in range(max(reps, 1)):
                out = fn()
            jax.tree.leaves(out)[0].block_until_ready()
            samples[tag].append((time.perf_counter() - t0) / max(reps, 1))
    return {tag: min(ts) for tag, ts in samples.items()}
