"""Reproduce the §Perf hillclimb: run baseline vs optimized variants for
the three chosen cells and print the before/after roofline comparison.

  PYTHONPATH=src python -m benchmarks.hillclimb          # ~10 min on CPU
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

CELLS = [  # (arch, shape, optimized variant)
    ("gemma2-9b", "train_4k", "opt"),
    ("seamless-m4t-large-v2", "train_4k", "opt"),
    ("olmoe-1b-7b", "train_4k", "vpz"),
]
OUT = "benchmarks/artifacts/dryrun"


def run(arch, shape, variant):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", OUT]
    if variant != "base":
        cmd += ["--variant", variant]
    env = dict(os.environ, PYTHONPATH="src")
    subprocess.run(cmd, check=True, env=env, capture_output=True, text=True)


def load(arch, shape, variant):
    tag = f"{arch.replace('-', '_').replace('.', '_')}-{shape}-pod1"
    if variant != "base":
        tag += f"-{variant}"
    with open(os.path.join(OUT, tag + ".json")) as f:
        return json.load(f)


def main():
    print("cell,variant,peak_GiB,compute_ms,memory_ms,collective_ms,dominant")
    for arch, shape, var in CELLS:
        for v in ("base", var):
            try:
                d = load(arch, shape, v)
            except FileNotFoundError:
                run(arch, shape, v)
                d = load(arch, shape, v)
            r = d["roofline_s"]
            print(f"{arch}/{shape},{v},"
                  f"{d['per_device']['peak_bytes']/2**30:.2f},"
                  f"{r['compute']*1e3:.1f},{r['memory']*1e3:.1f},"
                  f"{r['collective']*1e3:.1f},{d['dominant']}")


if __name__ == "__main__":
    main()
