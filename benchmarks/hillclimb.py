"""Strategy-search baselines head-to-head on the §V cost model: greedy
(narrow candidates, the longest-path-first default), exact DP over the
WIDE candidate set (what `--search beam` resolves to on a layer line),
and stochastic hill-climbing restarts over the same wide set.

  PYTHONPATH=src python -m benchmarks.hillclimb

Model-only — no live devices, no measurement: the point is ordering.
The wide candidate set is a strict superset of the narrow one, so on a
line (where the DP is exact) wide-DP <= greedy must hold identically;
hillclimb is the sanity bound from below — a stochastic search over the
same space may tie the DP but never beat it.  A violation of either
inequality is a solver bug, and `derived` makes it visible per cell.

CSV: name,us_per_call,derived — us_per_call is the found plan's predicted
step cost; derived carries the cost ratios vs greedy and vs the wide DP.
"""
from __future__ import annotations

MESHES = {"2x2": {"data": 2, "model": 2}, "4x4": {"data": 4, "model": 4}}


def _solve_all(m, specs, mesh_shape, table=None):
    """(greedy, wide_dp, hillclimb) predicted costs for one workload.
    greedy is None when the narrow candidate set leaves some layer with
    NO valid assignment (every mesh axis must land on a dim that divides)
    — the infeasibility the wide set's partial-replication target fixes,
    which is worth a row of its own, not a crash."""
    from repro.core import strategy as st
    narrow = [st.candidate_dists(l, mesh_shape, allow_channel_filter=True)
              for l in specs]
    wide = [st.candidate_dists(l, mesh_shape, allow_channel_filter=True,
                               wide=True) for l in specs]
    greedy = st.solve_line(m, specs, narrow, mesh_shape, table=table).cost \
        if all(narrow) else None
    dp = st.solve_line(m, specs, wide, mesh_shape, table=table)
    hc = st.solve_hillclimb(m, specs, wide, mesh_shape, table=table)
    return greedy, dp.cost, hc.cost


def run(csv=True):
    from repro.analysis.workloads import CFG16, CFG16P
    from repro.core import perfmodel as pm
    from repro.models.cnn import meshnet

    m = pm.TPU_V5E
    rows = []
    for wl, cfg, batch in (("mesh16cf", CFG16, 2),
                           ("mesh16_proxy", CFG16P, 1)):
        specs = meshnet.layer_specs(cfg, batch)
        for mname, mesh_shape in MESHES.items():
            greedy, dp, hc = _solve_all(m, specs, mesh_shape)
            if greedy is None:
                rows.append((f"hillclimb/{wl}/{mname}/greedy", 0.0,
                             "UNSOLVABLE: a layer has no narrow candidate"
                             " (the wide set's R target fixes this)"))
                vs_g_dp = vs_g_hc = "vs_greedy=n/a"
            else:
                rows.append((f"hillclimb/{wl}/{mname}/greedy",
                             greedy * 1e6,
                             "baseline (narrow candidates, DP)"))
                vs_g_dp = f"vs_greedy={dp / greedy:.3f}"
                vs_g_hc = f"vs_greedy={hc / greedy:.3f}"
            rows.append((f"hillclimb/{wl}/{mname}/wide_dp", dp * 1e6,
                         f"{vs_g_dp} (must be <= 1: superset space)"))
            rows.append((f"hillclimb/{wl}/{mname}/hillclimb", hc * 1e6,
                         f"{vs_g_hc} vs_wide_dp={hc / dp:.3f} "
                         f"(must be >= 1: DP is exact)"))
    if csv:
        for n_, v, d_ in rows:
            print(f"{n_},{v:.1f},{d_}")
    return rows


if __name__ == "__main__":
    run()
