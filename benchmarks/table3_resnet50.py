"""Paper Table III: ResNet-50 strong scaling — sample (32 samples/GPU) vs
hybrid sample+spatial (32 samples / 2 or 4 GPUs).  Calibrate on
(N=128, sample) + (N=128, hybrid4); predict the rest.  The headline claims
to reproduce: ~1.4x speedup at 2x GPUs, ~1.5-1.8x at 4x GPUs.
CSV: name,us_per_call,derived."""
import numpy as np

from benchmarks import _paper_data as D
from repro.models.cnn import resnet


def run(csv=True):
    layer_fn = lambda n: resnet.layer_specs(n)
    m = D.fit_machine(layer_fn, D.TABLE3, [(128, 1), (128, 4)], group=32,
                      name="lassen-resnet50")
    rows, errs, speeds = [], [], {2: [], 4: []}
    for N, row in D.TABLE3.items():
        base = None
        for p, t in row.items():
            pred = D.predict(m, layer_fn(N), N // 32, p)
            err = pred / t - 1
            if (N, p) not in [(128, 1), (128, 4)]:
                errs.append(abs(err))
            if p == 1:
                base = pred
            elif base:
                speeds[p].append(base / pred)
            rows.append((f"table3/N{N}/{'sample' if p == 1 else f'hyb{p}'}",
                         pred * 1e6,
                         f"paper={t*1e6:.0f}us err={err*100:+.1f}%"))
    for p, s in speeds.items():
        rows.append((f"table3/speedup_hybrid{p}", np.mean(s) * 100,
                     f"predicted {np.mean(s):.2f}x vs paper ~"
                     f"{'1.4x' if p == 2 else '1.5-1.8x'}"))
    rows.append(("table3/mean_abs_err_heldout", np.mean(errs) * 1e2, ""))
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.1f},{d}")
    return rows, np.mean(errs)


if __name__ == "__main__":
    run()
