"""Quickstart: the paper's pipeline — solve, compile, execute — in one
minute on CPU devices.

1. Build the (reduced) mesh-tangling model.
2. Run the strategy optimizer (paper §V-C) on its layer line for this
   mesh, and ALSO show what it would pick on a hypothetical 2x2 mesh —
   including a hand-mixed spatial + channel/filter (§III-D) plan with
   explicit reshard points at the transitions.
3. Compile the solved strategy into an executable NetworkPlan (per-layer
   shardings + §III-C reshard points, core.plan) and train a few steps
   WITH that plan; checkpoint and resume.
4. Trace the plan: segmented per-layer profiling (core.trace) joined
   against the model's predictions (plan.attribution_report).

  PYTHONPATH=src python examples/quickstart.py
"""
import functools
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import perfmodel as pm
from repro.core import plan as plan_lib
from repro.data.pipeline import synthetic_mesh_batch
from repro.launch.mesh import make_mesh
from repro.models.cnn import meshnet
from repro.optim.optimizer import sgd
from repro.utils import human_count, tree_num_params

cfg = meshnet.MeshNetConfig("quickstart", input_hw=64, in_channels=4,
                            convs_per_block=1, widths=(8, 16, 16))
params = meshnet.init(jax.random.PRNGKey(0), cfg)
print(f"model: {cfg.name}, {human_count(tree_num_params(params))} params")

machine = pm.TPU_V5E
BATCH = 4
layers = meshnet.layer_specs(cfg, n=BATCH)

# --- what would the optimizer do on a (hypothetical) 2x2 mesh? -----------
hypo = plan_lib.plan_line(machine, layers, {"data": 2, "model": 2})
print("\nsolved plan for a hypothetical 2x2 mesh (paper §V-C):")
print(hypo.describe())

# --- mixed spatial + channel/filter plan (§III-D) ------------------------
# The early layer keeps the hybrid sample x spatial decomposition (large
# H, few channels); the later layers switch to channel/filter parallelism
# (small H, many channels) — core.channel_conv's row-parallel conv.  Each
# transition compiles to one §III-C reshard point.
from repro.core.distribution import Dist, channel_filter, hybrid
mixed = plan_lib.compile_plan(
    {"conv1_1": hybrid(),                 # N on data, H on model
     "conv2_1": channel_filter(),         # N on data, C&F on model
     "conv3_1": channel_filter(),         # chains with zero resharding
     "pred": Dist("sample", {"N": ("data", "model")})},
    layers, {"data": 2, "model": 2}, machine=machine)
print("\nhand-mixed spatial + CF plan on the same 2x2 mesh:")
print(mixed.describe())

# --- a 16-device (4x2x2) mesh: the decompositions 16x16 meshes need ------
# Two families unlocked by the product-axis halo (core.halo) and the
# CF x spatial composition (core.channel_conv):
#   * H split over a *tuple* of mesh axes — one linearized product axis, so
#     ('data','model') behaves like a single 4-way spatial axis;
#   * CF on one axis composed with spatial sharding on others — the halo
#     exchange and the CF collective live inside ONE shard_map.
MS16 = {"pod": 4, "data": 2, "model": 2}
auto16 = plan_lib.plan_line(machine, layers, MS16)
print("\nsolved plan for a hypothetical 16-device (4x2x2) mesh:")
print(auto16.describe())

mixed16 = plan_lib.compile_plan(
    {"conv1_1": Dist("s+h2", {"N": ("pod",), "H": ("data", "model")}),
     "conv2_1": Dist("cf*h", {"N": ("pod",), "H": ("data",),
                              "C": ("model",), "F": ("model",)}),
     "conv3_1": Dist("cf*h", {"N": ("pod",), "H": ("data",),
                              "C": ("model",), "F": ("model",)}),
     "pred": Dist("s+h2", {"N": ("pod",), "H": ("data", "model")})},
    layers, MS16, machine=machine)
print("\nhand-mixed sample + two-axis-spatial + CF x spatial plan "
      "(consecutive CF layers chain; each family change is one reshard):")
print(mixed16.describe())

# --- memory-aware planning (--mem-limit): the paper's Table-2 story ------
# When batch < devices, sample parallelism cannot reduce per-device memory
# below one sample — the 2K mesh-tangling workload is "unreachable" on a
# 16 GB device (paper §VI).  Solving the same network with and without the
# capacity limit shows the change: the sample-parallel plan's cost report
# carries a peak ABOVE the limit (the plan you cannot run), while the
# --mem-limit solve answers with a spatial plan whose report fits — both
# reports expose plan.predicted['memory'] (per-layer breakdowns + peak).
layers2 = meshnet.layer_specs(cfg, n=2)       # batch 2 < 4 devices (§VI)
MS22 = {"data": 2, "model": 2}
sample2 = plan_lib.compile_plan(
    {l.name: Dist("sample", {"N": ("data",)}) for l in layers2},
    layers2, MS22, machine=machine)           # no limit: report only
sample_peak = sample2.predicted["memory"]["peak_bytes"]
limit = 0.75 * sample_peak                    # a device 3/4 that size
print(f"\nuniform sample-parallel at batch 2 — stuck at one sample per "
      f"device, peak ABOVE the {limit:.0f}-byte limit:")
print(sample2.describe())
fit2 = plan_lib.plan_line(machine, layers2, MS22, mem_limit=limit)
print(f"\nsolved WITH --mem-limit {limit:.0f} "
      f"(min-time subject to the fit — spatial buys the memory down):")
print(fit2.describe())
for name, lm in fit2.predicted["memory"]["per_layer"].items():
    print(f"  {name:10s} {lm.total / 2**10:7.1f} KiB  ({lm.breakdown()})")

# --- solve + compile for THIS machine's devices, then execute it ---------
mesh = make_mesh(data=1, model=jax.device_count())
plan = plan_lib.plan_line(machine, layers, mesh)
print(f"\nexecuting on mesh {dict(mesh.shape)}:")
print(plan.describe())

# --- static audit: prove costed == executed BEFORE spending a step -------
# repro.analysis lints the solved plan (divisibility, reshard coverage,
# memory fit, spec round-trip) and traces the jaxpr of one training step,
# joining every collective it finds against the cost model's priced
# inventory — an unpriced collective or phantom charge is an error-severity
# Finding.  The train driver runs the same gate via `--audit`.
from repro import analysis
findings = plan.audit(layers, mesh, cfg=cfg, overlap=True, hlo=False)
print(f"\nstatic audit of the executing plan "
      f"({len(findings)} finding(s), "
      f"{analysis.error_count(findings)} error(s)):")
print(analysis.format_findings(findings))
assert analysis.error_count(findings) == 0

loss_fn = functools.partial(meshnet.loss_fn, cfg=cfg, plan=plan, mesh=mesh)
opt = sgd(0.05, momentum=0.9)
state = opt.init(params)


@jax.jit
def step(p, s, batch):
    l, g = jax.value_and_grad(loss_fn)(p, batch)
    p, s = opt.update(g, s, p)
    return p, s, l


ckdir = tempfile.mkdtemp()
ck = CheckpointManager(ckdir, async_save=False)
print("\ntraining under the compiled plan:")
for i in range(10):
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(i, BATCH, 64, 4, out_hw=8).items()}
    params, state, l = step(params, state, b)
    if i % 3 == 0:
        print(f"  step {i}: loss {float(l):.4f}")
ck.save(10, (params, state))
(params, state), manifest = ck.restore((params, state))
print(f"checkpoint round-trip ok (step {manifest['step']})")

# --- elastic fault tolerance: kill the run mid-step, watch it recover ----
# The resilient loop (runtime/fault_tolerance.py) checkpoints every N
# steps — manifests carry the solved plan spec (repro/ckpt@1), so a
# restart on a DIFFERENT mesh can reshard-on-restore — and on a fault
# rolls back to the last checkpoint and replays the same step-indexed
# batches, so the recovered trajectory is the uninterrupted one.
# chaos.raise_at_step simulates the crash; the train driver's --elastic
# flag adds the full story (device loss -> remesh onto survivors ->
# re-solve under the same --mem-limit), see README "Elastic &
# fault-tolerant training".
from repro.runtime import chaos
from repro.runtime.fault_tolerance import ResilientLoop, StragglerMonitor


def make_step():
    def run(st, i):
        b = {k: jnp.asarray(v) for k, v in
             synthetic_mesh_batch(i, BATCH, 64, 4, out_hw=8).items()}
        p, s, l = step(st[0], st[1], b)
        return (p, s), {"loss": float(l)}
    return run


ck2 = CheckpointManager(tempfile.mkdtemp(), async_save=False)
loop = ResilientLoop(ckpt=ck2, make_step=make_step, ckpt_every=3,
                     plan_spec=lambda: plan.to_spec(dict(mesh.shape)))
(params, state), end, m = loop.run((params, state), 0, 8,
                                   monitor=StragglerMonitor(),
                                   inject_failure=chaos.raise_at_step(5))
rec = ck2.read_manifest()["plan"]
print(f"faulted at step 5, rolled back to the step-3 checkpoint, replayed "
      f"to step {end} (loss {m['loss']:.4f}); manifest records the plan "
      f"solved on mesh {rec['mesh']}")

# --- trace the plan: measured per-layer cost vs the model's prediction ---
# core.trace re-executes each layer in isolation (AOT-compiled fwd and
# fwd+bwd, interleaved-min timing) and the attribution report joins the
# measured seconds against the plan's predicted LayerCost terms — the
# per-term drift line names which §V cost term the model gets most wrong.
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.trace import trace_plan, format_attribution
b = {k: jnp.asarray(v) for k, v in
     synthetic_mesh_batch(0, BATCH, 64, 4, out_hw=8).items()}
first = layers[0]
spec = plan.input_spec(first.name, first.h, first.w, first.k, first.s, mesh)
batch = {"image": jax.device_put(b["image"], NamedSharding(mesh, spec)),
         "label": jax.device_put(b["label"], NamedSharding(mesh, P("data")))}
trace = trace_plan(plan, params, batch, cfg=cfg, mesh=mesh,
                   reps=2, rounds=2)
print(f"\ntraced {len(trace.layers)} layers "
      f"(per-layer sum {trace.layer_sum_s * 1e3:.2f} ms, "
      f"fused step {trace.step['fwd_bwd_s'] * 1e3:.2f} ms):")
print(format_attribution(plan.attribution_report(trace)))
# trace.save("step_trace.json"); trace.save_chrome("step_trace.chrome.json")
print("done.")
