"""Quickstart: the paper's pipeline in one minute on one CPU device.

1. Build the (reduced) mesh-tangling model.
2. Ask the strategy optimizer (paper §V-C) how to parallelize it on a
   hypothetical 2x2 mesh.
3. Train a few steps with the resilient loop; checkpoint and resume.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import perfmodel as pm, strategy as strat
from repro.core.spatial_conv import ConvSharding
from repro.data.pipeline import synthetic_mesh_batch
from repro.models.cnn import meshnet
from repro.optim.optimizer import sgd
from repro.utils import human_count, tree_num_params

cfg = meshnet.MeshNetConfig("quickstart", input_hw=64, in_channels=4,
                            convs_per_block=1, widths=(8, 16, 16))
params = meshnet.init(jax.random.PRNGKey(0), cfg)
print(f"model: {cfg.name}, {human_count(tree_num_params(params))} params")

# --- what would the paper's strategy optimizer do on a 2x2 mesh? ---------
machine = pm.TPU_V5E
layers = meshnet.layer_specs(cfg, n=8)
mesh_shape = {"data": 2, "model": 2}
cands = [strat.candidate_dists(l, mesh_shape) for l in layers]
res = strat.solve_line(machine, layers, cands, mesh_shape)
print("\nper-layer parallel execution strategy (paper §V-C):")
for l, d in zip(layers, res.dists):
    print(f"  {l.name:12s} {l.h:4d}x{l.w:<4d} -> {dict(d.dims)}")
print(f"predicted mini-batch time: {res.cost*1e3:.2f} ms")

# --- train a few steps, checkpoint, resume -------------------------------
loss_fn = functools.partial(meshnet.loss_fn, cfg=cfg,
                            shardings=ConvSharding())
opt = sgd(0.05, momentum=0.9)
state = opt.init(params)


@jax.jit
def step(p, s, batch):
    l, g = jax.value_and_grad(loss_fn)(p, batch)
    p, s = opt.update(g, s, p)
    return p, s, l


ckdir = tempfile.mkdtemp()
ck = CheckpointManager(ckdir, async_save=False)
print("\ntraining:")
for i in range(10):
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(i, 4, 64, 4, out_hw=8).items()}
    params, state, l = step(params, state, b)
    if i % 3 == 0:
        print(f"  step {i}: loss {float(l):.4f}")
ck.save(10, (params, state))
(params, state), manifest = ck.restore((params, state))
print(f"checkpoint round-trip ok (step {manifest['step']})")
print("done.")
