"""The paper, end to end on 8 (emulated) devices: hybrid sample x spatial
training of a mesh-tangling model with halo-exchange convolution, fault
injection + checkpoint restart, and int8 error-feedback gradient
compression across the pod axis.

  PYTHONPATH=src python examples/spatial_parallel_cnn.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import functools            # noqa: E402
import tempfile             # noqa: E402

import jax                  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint.checkpoint import CheckpointManager      # noqa: E402
from repro.core.spatial_conv import ConvSharding               # noqa: E402
from repro.data.pipeline import synthetic_mesh_batch           # noqa: E402
from repro.launch.mesh import make_mesh                        # noqa: E402
from repro.models.cnn import meshnet                           # noqa: E402
from repro.optim.optimizer import sgd                          # noqa: E402
from repro.runtime.fault_tolerance import (ResilientLoop,      # noqa: E402
                                           StragglerMonitor)
from repro.train.train_loop import (TrainStepConfig,           # noqa: E402
                                    make_train_step, shard_tree)
from repro.utils import FP32                                   # noqa: E402

mesh = make_mesh(pod=2, data=2, model=2)
print(f"mesh: {dict(mesh.shape)} "
      "(pod = cross-pod DP, data = sample parallelism, "
      "model = the paper's spatial axis)")

cfg = meshnet.MeshNetConfig("spatial-demo", input_hw=64, in_channels=4,
                            convs_per_block=1, widths=(8, 16, 16))
sharding = ConvSharding(batch_axes=("pod", "data"), h_axis="model")
params = shard_tree(meshnet.init(jax.random.PRNGKey(0), cfg), mesh,
                    lambda x: P())
loss = functools.partial(meshnet.loss_fn, cfg=cfg, plan=sharding,
                         mesh=mesh)
opt = sgd(0.05, momentum=0.9)
step_fn = make_train_step(
    lambda p, b: loss(p, b), opt, mesh,
    TrainStepConfig(grad_accum=2, precision=FP32,
                    pod_compression="int8_ef"))


def put(b):
    return {"image": jax.device_put(b["image"], NamedSharding(
                mesh, P(("pod", "data"), "model"))),
            "label": jax.device_put(b["label"], NamedSharding(
                mesh, P(("pod", "data"),)))}


ck = CheckpointManager(tempfile.mkdtemp(), keep=2)
state = (params, opt.init(params), None)


def make_step():
    def run(state, step):
        p, o, ef = state
        p, o, ef, m = step_fn(p, o, ef,
                              put(synthetic_mesh_batch(step, 8, 64, 4,
                                                       out_hw=8)))
        if step % 5 == 0:
            print(f"  step {step}: loss {float(m['loss']):.4f}")
        return (p, o, ef), m
    return run


armed = {"on": True}


def inject(step):
    if step == 8 and armed["on"]:
        armed["on"] = False
        print("  !! injecting node failure at step 8")
        raise RuntimeError("synthetic failure")


loop = ResilientLoop(ckpt=ck, make_step=make_step, ckpt_every=5)
state, step, metrics = loop.run(state, 0, 20, monitor=StragglerMonitor(),
                                inject_failure=inject)
print(f"survived the failure; finished at step {step}, "
      f"loss {float(metrics['loss']):.4f}")
