"""End-to-end training driver: a scaled mesh-tangling model (the paper's
workload family, ~21M params like the paper's own 1K model) trained for a
few hundred steps through the production path — prefetching pipeline,
mixed-precision train step, async checkpointing, resilient loop.

CPU note: the full 1024^2 model is a multi-hour CPU job; the default here
is the same network at 128^2 inputs (identical depth/widths => identical
parameter count, 1/64 the pixels).  Pass --full for the paper's 1K config,
--steps to change length.

  PYTHONPATH=src python examples/train_mesh_e2e.py [--steps 300] [--full]
"""
import argparse
import functools
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.spatial_conv import ConvSharding
from repro.data.pipeline import Prefetcher, synthetic_mesh_batch
from repro.models.cnn import meshnet
from repro.optim.optimizer import sgd, warmup_cosine
from repro.runtime.fault_tolerance import ResilientLoop, StragglerMonitor
from repro.train.train_loop import TrainStepConfig, make_train_step
from repro.utils import FP32, human_count, tree_num_params

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--full", action="store_true",
                help="paper's true 1024^2 input size")
args = ap.parse_args()

hw = 1024 if args.full else 128
cfg = meshnet.MeshNetConfig("mesh-e2e", input_hw=hw, in_channels=18,
                            convs_per_block=3)
params = meshnet.init(jax.random.PRNGKey(0), cfg)
print(f"{cfg.name}: {human_count(tree_num_params(params))} params "
      f"(paper's 1K-model family), input {hw}^2 x 18")

loss = functools.partial(meshnet.loss_fn, cfg=cfg, plan=ConvSharding())
opt = sgd(warmup_cosine(0.02, 20, args.steps), momentum=0.9)


class _NoMesh:
    axis_names = ()


tstep = make_train_step(lambda p, b: loss(p, b), opt, _NoMesh(),
                        TrainStepConfig(precision=FP32))
ck = CheckpointManager(tempfile.mkdtemp(), keep=2, async_save=True)
pf = Prefetcher(lambda s: synthetic_mesh_batch(
    s, args.batch, hw, 18, out_hw=cfg.out_hw))
state = (params, opt.init(params), None)
t0 = time.time()
hist = []


def make_step():
    def run(state, step):
        p, o, ef = state
        b = {k: jnp.asarray(v) for k, v in next(pf).items()}
        p, o, ef, m = tstep(p, o, ef, b)
        hist.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss {hist[-1]:.4f} "
                  f"({(time.time()-t0)/(len(hist)):.2f}s/step)")
        return (p, o, ef), m
    return run


loop = ResilientLoop(ckpt=ck, make_step=make_step, ckpt_every=100)
state, step, _ = loop.run(state, 0, args.steps, monitor=StragglerMonitor())
pf.close()
print(f"trained {step} steps in {time.time()-t0:.0f}s; "
      f"loss {hist[0]:.4f} -> {hist[-1]:.4f}")
assert hist[-1] < hist[0]
