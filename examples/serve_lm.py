"""Batched LM serving with a sequence-sharded KV cache (the paper's spatial
decomposition applied to inference): prefill a batch of prompts with ring
attention, then greedy-decode with flash-decoding-style partial-softmax
merges across the sequence shards.

  PYTHONPATH=src python examples/serve_lm.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402
import numpy as np          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry                         # noqa: E402
from repro.launch import shardings as SH                   # noqa: E402
from repro.launch.mesh import make_mesh                    # noqa: E402
from repro.models.lm import transformer as T               # noqa: E402
from repro.models.lm.modules import ShardCtx               # noqa: E402

mesh = make_mesh(data=2, model=4)
ctx = ShardCtx(mesh=mesh, seq_axis="model", batch_axes=("data",))
cfg = registry.get("qwen1_5_0_5b", smoke=True)
params = T.init(jax.random.PRNGKey(0), cfg)

B, PROMPT, GEN = 2, 16, 12
MAXLEN = ((PROMPT + GEN + 3) // 4) * 4     # multiple of the seq shards
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, PROMPT), np.int32))

with mesh:
    # empty sharded cache; replay the prompt through the decode path, then
    # generate.  (Bulk prefill via T.prefill exercises ring attention.)
    caches = T.init_decode_state(params, cfg, B, MAXLEN, jnp.float32)
    cspecs = SH.kv_cache_specs(caches, mesh, True, "model")
    caches = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        caches, cspecs)
    decode = jax.jit(lambda p, t, c, L: T.decode_step(p, cfg, t, c, L, ctx),
                     donate_argnums=(2,))
    tok = prompts[:, :1]
    generated = []
    for i in range(PROMPT + GEN - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < PROMPT:
            tok = prompts[:, i + 1:i + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            generated.append(np.asarray(tok)[:, 0])

print(f"served batch={B} on mesh {dict(mesh.shape)} "
      f"(KV cache sharded over 'model')")
print("generated ids:\n", np.stack(generated, 1))
