"""Test config.  The main pytest process keeps ONE CPU device — multi-device
checks run in subprocesses (tests/dist_checks.py), and the 512-device env is
reserved for the dry-run (launch/dryrun.py)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dist_group(group: str, timeout: int = 560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "dist_checks.py"),
         group],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)
    if r.returncode != 0:
        raise AssertionError(
            f"dist_checks {group} failed:\n{r.stdout}\n{r.stderr[-4000:]}")


@pytest.fixture(scope="session")
def repo_root():
    return REPO
