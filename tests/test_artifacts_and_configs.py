"""Deliverable-level invariants: the dry-run artifact matrix is complete
and healthy; every assigned (arch x shape) cell divides the production
mesh; registry metadata is coherent."""
import json
import os

import pytest

from repro.configs import registry

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "artifacts", "dryrun")

HAS_ARTIFACTS = os.path.isdir(ART) and len(os.listdir(ART)) > 0


def _load(tag):
    with open(os.path.join(ART, tag + ".json")) as f:
        return json.load(f)


@pytest.mark.skipif(not HAS_ARTIFACTS, reason="run repro.launch.sweep first")
@pytest.mark.parametrize("arch", registry.ARCHS)
@pytest.mark.parametrize("shape", list(registry.SHAPES))
@pytest.mark.parametrize("pod", ["pod1", "pod2"])
def test_dryrun_cell_ok(arch, shape, pod):
    """All 80 LM cells compiled on both production meshes (deliverable e)."""
    d = _load(f"{arch}-{shape}-{pod}")
    assert d["ok"], d.get("error")
    assert d["chips"] == (512 if pod == "pod2" else 256)
    pd = d["per_device"]
    assert pd["flops"] > 0
    assert pd["hbm_bytes"] > 0
    assert d["roofline_s"]["compute"] >= 0
    # decode steps must be cheap in compute; training must not be
    kind = registry.SHAPES[shape]["kind"]
    if kind == "train":
        assert d["roofline_s"]["compute"] > 1e-3
    # every cell records a dominant bottleneck from the three terms
    assert d["dominant"] in ("compute", "memory", "collective")


@pytest.mark.skipif(not HAS_ARTIFACTS, reason="run repro.launch.sweep first")
@pytest.mark.parametrize("arch", registry.CNN_ARCHS)
def test_dryrun_cnn_cells_ok(arch):
    for pod in ("pod1", "pod2"):
        d = _load(f"{arch}-cnn-{pod}")
        assert d["ok"]


def test_hillclimb_bench_orderings_hold():
    """benchmarks/hillclimb (the strategy-search baseline) must uphold its
    own invariants on every cell: the wide-candidate exact DP never
    predicts worse than greedy (superset space), and stochastic
    hill-climbing never beats the exact DP."""
    from benchmarks import hillclimb
    rows = {name: (us, derived)
            for name, us, derived in hillclimb.run(csv=False)}
    assert rows, "the bench must emit cells"
    cells = {n.rsplit("/", 1)[0] for n in rows}
    for cell in cells:
        g_us, g_note = rows[f"{cell}/greedy"]
        dp_us, _ = rows[f"{cell}/wide_dp"]
        hc_us, _ = rows[f"{cell}/hillclimb"]
        if "UNSOLVABLE" not in g_note:
            assert dp_us <= g_us + 1e-9, cell
        assert hc_us >= dp_us - 1e-9, cell


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_shapes_divide_production_mesh(arch):
    """Every assigned cell's tensors divide the 16x16 mesh factors."""
    cfg = registry.get(arch)
    for shape, info in registry.SHAPES.items():
        seq, gb, kind = info["seq_len"], info["global_batch"], info["kind"]
        assert seq % 16 == 0                       # model axis
        if gb >= 16:
            assert gb % 16 == 0                    # data axis
        elif kind == "decode":
            assert seq % 256 == 0                  # (data, model) KV shard
    # layer plan covers every layer exactly once
    from repro.models.lm.transformer import plan
    total = sum(len(unit) * count for unit, count in plan(cfg))
    assert total == cfg.n_layers


def test_registry_aliases():
    for alias in ["gemma2-9b", "qwen2.5-14b", "seamless-m4t-large-v2",
                  "mixtral-8x7b"]:
        assert registry.canon(alias) in registry.ARCHS
    assert len(registry.ARCHS) == 10
    assert len(registry.SHAPES) == 4  # 40 LM cells


def test_full_attn_flags():
    """DESIGN.md §Arch-applicability: sub-quadratic archs are not flagged."""
    for a in ("mamba2_780m", "hymba_1_5b", "gemma2_9b", "mixtral_8x7b"):
        assert a not in registry.FULL_ATTN_500K
    for a in ("qwen2_5_14b", "olmo_1b", "pixtral_12b"):
        assert a in registry.FULL_ATTN_500K
