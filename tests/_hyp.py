"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency (pinned in requirements-dev.txt); the
runtime image may not have it.  A bare `from hypothesis import ...` at module
scope kills `pytest -x` at *collection*, taking every non-property test in
the module down with it.  Importing the names from here instead gives
`pytest.importorskip("hypothesis")` semantics at per-test granularity: when
hypothesis is absent, @given-decorated tests skip cleanly and everything
else in the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for hypothesis.strategies: any strategy call -> None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco
