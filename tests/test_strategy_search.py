"""Widened strategy search (§V-C generalized): wide candidate sets,
reshard-aware beam DP, hill-climbing baseline, and the fast-lane
regression pin that the widened search never predicts worse than greedy.
"""
import dataclasses

import pytest

from repro.core import perfmodel as pm
from repro.core import strategy as strat
from repro.core.plan import plan_line
from repro.models.cnn import meshnet

M = pm.TPU_V5E
MS22 = {"data": 2, "model": 2}
MS42 = {"data": 4, "model": 2}

CFG = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                            convs_per_block=1, widths=(8, 16))
SPECS = meshnet.layer_specs(CFG, 4)


# ------------------------------------------------------- candidate space --
def test_wide_candidates_are_a_superset():
    """The widened set must contain every narrow candidate on every layer
    of both meshes — the beam <= greedy ordering below rests on it."""
    for ms in (MS22, MS42):
        for layer in SPECS:
            narrow = strat.candidate_dists(layer, ms,
                                           allow_channel_filter=True)
            wide = strat.candidate_dists(layer, ms,
                                         allow_channel_filter=True,
                                         wide=True)
            keys = {repr(d.dims) for d in wide}
            assert len(wide) >= len(narrow)
            for d in narrow:
                assert repr(d.dims) in keys, (layer.name, d.dims)


def test_wide_rescues_layers_narrow_cannot_assign():
    """A layer whose dims cannot absorb every mesh axis (here f=1, n=2 on
    a 4x4 mesh) has NO narrow candidate; the wide set's partial-
    replication target ('R': leave the axis unassigned) makes it
    solvable."""
    layer = pm.ConvLayer("pred", n=2, c=64, h=2, w=2, f=1, k=1, s=1)
    ms = {"data": 4, "model": 4}
    assert strat.candidate_dists(layer, ms, allow_channel_filter=True) == []
    wide = strat.candidate_dists(layer, ms, allow_channel_filter=True,
                                 wide=True)
    assert wide, "partial replication must make the layer assignable"


# ----------------------------------------------- the search-mode promise --
@pytest.mark.parametrize("ms", [MS22, MS42], ids=["2x2", "4x2"])
def test_beam_predicted_never_worse_than_greedy(ms):
    """The fast-lane search-regression pin: on a layer line the widened
    search is the exact DP over a superset space, so its predicted total
    can only be <= the greedy (narrow longest-path-first) solve's."""
    greedy = plan_line(M, SPECS, ms, search="greedy")
    beam = plan_line(M, SPECS, ms, search="beam:4")
    assert beam.predicted["total"] <= greedy.predicted["total"] + 1e-15


def test_hillclimb_never_beats_exact_dp():
    cands = [strat.candidate_dists(l, MS22, allow_channel_filter=True,
                                   wide=True) for l in SPECS]
    dp = strat.solve_line(M, SPECS, cands, MS22)
    hc = strat.solve_hillclimb(M, SPECS, cands, MS22)
    assert hc.cost >= dp.cost - 1e-15
    assert len(hc.dists) == len(SPECS)


def test_hillclimb_deterministic_under_seed():
    cands = [strat.candidate_dists(l, MS22, allow_channel_filter=True,
                                   wide=True) for l in SPECS]
    a = strat.solve_hillclimb(M, SPECS, cands, MS22, seed=7)
    b = strat.solve_hillclimb(M, SPECS, cands, MS22, seed=7)
    assert a.cost == b.cost
    assert [d.dims for d in a.dists] == [d.dims for d in b.dists]


def test_hillclimb_search_mode_solves_plan():
    p = plan_line(M, SPECS, MS22, search="hillclimb")
    assert p.predicted["total"] > 0
    assert set(p.layers) == set(meshnet.layer_names(CFG))


# ------------------------------------------------------------ beam on DAG --
def test_beam_dag_prices_every_edge():
    """solve_dag_beam charges the reshard on EVERY incoming DAG edge (the
    greedy solver zeroes edges into already-fixed layers), so on a
    diamond it must return a valid assignment for every node."""
    nx = pytest.importorskip("networkx")
    g = nx.DiGraph()
    mk = lambda nm: pm.ConvLayer(nm, n=4, c=8, h=32, w=32, f=8,  # noqa:E731
                                 k=3, s=1)
    for nm in ("a", "b", "c", "d"):
        g.add_node(nm, layer=mk(nm))
    g.add_edges_from([("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")])
    dists = strat.solve_dag_beam(M, g, MS22, width=4)
    assert set(dists) == {"a", "b", "c", "d"}
    for d in dists.values():
        assert d is not None


# -------------------------------------------------------------- the CLI --
def test_parse_search():
    assert strat.parse_search("greedy") == ("greedy", 0)
    assert strat.parse_search("beam") == ("beam", 4)
    assert strat.parse_search("beam:9") == ("beam", 9)
    assert strat.parse_search("hillclimb") == ("hillclimb", 0)
    with pytest.raises(ValueError):
        strat.parse_search("anneal")
    with pytest.raises(ValueError):
        strat.parse_search("beam:0")


def test_search_factors_do_not_change_narrow_greedy():
    """`--search greedy` stays bit-compatible with the pre-widening solve:
    same plan, same predicted total as calling plan_line without search."""
    a = plan_line(M, SPECS, MS22)
    b = plan_line(M, SPECS, MS22, search="greedy")
    assert a.predicted["total"] == b.predicted["total"]
    for n in a.layers:
        assert a.layers[n].dist.same_as(b.layers[n].dist)
