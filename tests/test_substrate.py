"""Substrate tests: optimizers, checkpointing, data pipeline, CNN models."""
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.spatial_conv import ConvSharding
from repro.data.pipeline import (Prefetcher, synthetic_lm_batch,
                                 synthetic_mesh_batch)
from repro.models.cnn import meshnet, resnet
from repro.optim.optimizer import adamw, clip_by_global_norm, sgd, \
    warmup_cosine
from repro.runtime.fault_tolerance import StragglerMonitor


# ------------------------------------------------------------- optimizers --
def test_sgd_quadratic():
    opt = sgd(0.05, momentum=0.9)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_warmup_cosine():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 0.11


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_sgd_descends(seed):
    """One SGD step on a convex quadratic never increases the loss."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.5, 2.0, size=(5,)))
    x0 = jnp.asarray(rng.normal(size=(5,)))
    loss = lambda x: jnp.sum(a * x ** 2)
    opt = sgd(0.01, momentum=0.0)
    st_ = opt.init({"x": x0})
    g = jax.grad(lambda p: loss(p["x"]))({"x": x0})
    new, _ = opt.update(g, st_, {"x": x0})
    assert float(loss(new["x"])) <= float(loss(x0)) + 1e-9


# ------------------------------------------------------------ checkpoints --
def test_checkpoint_roundtrip_and_rotation():
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, keep=2, async_save=False)
        tree = {"w": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.int32)}}
        for s in (5, 10, 15):
            ck.save(s, jax.tree.map(lambda x: x + s, tree))
        assert ck.latest_step() == 15
        got, manifest = ck.restore(tree)
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(tree["w"]) + 15)
        assert manifest["step"] == 15
        # rotation kept only 2
        steps = [f for f in os.listdir(d) if f.startswith("step-")]
        assert len(steps) == 2
    finally:
        shutil.rmtree(d)


def test_checkpoint_async_and_atomic():
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, keep=3, async_save=True)
        tree = {"w": jnp.zeros((256, 256))}
        ck.save(1, tree)
        ck.wait()
        assert ck.latest_step() == 1
        # no tmp- dirs left behind after commit
        assert not [f for f in os.listdir(d) if f.startswith("tmp-")]
    finally:
        shutil.rmtree(d)


def test_checkpoint_structure_mismatch_raises():
    from repro.checkpoint.checkpoint import CheckpointError
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_save=False)
        ck.save(1, {"w": jnp.zeros((3,))})
        with pytest.raises(CheckpointError, match="GLOBAL"):
            ck.restore({"w": jnp.zeros((4,))})
        with pytest.raises(CheckpointError, match="leaves"):
            ck.restore({"w": jnp.zeros((3,)), "b": jnp.zeros((2,))})
    finally:
        shutil.rmtree(d)


# ------------------------------------------------------------------- data --
def test_data_determinism():
    a = synthetic_lm_batch(7, 4, 16, 100)
    b = synthetic_lm_batch(7, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_mesh_batch(3, 2, 64, 4, out_hw=8)
    d = synthetic_mesh_batch(3, 2, 64, 4, out_hw=8)
    np.testing.assert_array_equal(c["image"], d["image"])
    assert c["image"].shape == (2, 64, 64, 4)


def test_prefetcher():
    pf = Prefetcher(lambda s: {"step": np.array([s])}, start_step=3)
    try:
        got = [next(pf)["step"][0] for _ in range(4)]
        assert got == [3, 4, 5, 6]
    finally:
        pf.close()


# -------------------------------------------------------------- straggler --
def test_straggler_monitor():
    mon = StragglerMonitor(k=5.0, warmup=3)
    for i in range(10):
        assert not mon.record(i, 0.1 + 0.001 * (i % 2))
    assert mon.record(10, 1.5)       # 15x median -> flagged
    assert mon.stats["flagged"] == 1


# ------------------------------------------------------------- CNN models --
def test_meshnet_shapes_and_loss():
    cfg = meshnet.MeshNetConfig("t", input_hw=64, in_channels=4,
                                convs_per_block=1, widths=(8, 16, 16))
    p = meshnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 4))
    y = meshnet.apply(p, x, cfg, ConvSharding())
    assert y.shape == (2, 8, 8, 1)
    lbl = (jax.random.uniform(jax.random.PRNGKey(2), y.shape) > .5) \
        .astype(jnp.float32)
    l = meshnet.loss_fn(p, {"image": x, "label": lbl}, cfg, ConvSharding())
    assert np.isfinite(float(l))


def test_resnet_shapes_and_loss():
    cfg = resnet.ResNetConfig(input_hw=32, n_classes=10, stages=(1, 1, 1, 1),
                              widths=(4, 8, 8, 8))
    p = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    out = resnet.apply(p, x, cfg)
    assert out.shape == (2, 10)
    l = resnet.loss_fn(p, {"image": x, "label": jnp.array([1, 2])}, cfg)
    assert np.isfinite(float(l))


def test_cnn_training_decreases_loss():
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=2,
                                convs_per_block=1, widths=(4, 8))
    p = meshnet.init(jax.random.PRNGKey(0), cfg)
    opt = sgd(0.05, momentum=0.9)
    s = opt.init(p)
    step = jax.jit(lambda p, s, b: _one(p, s, b))

    def _one(p, s, b):
        l, g = jax.value_and_grad(meshnet.loss_fn)(p, b, cfg, ConvSharding())
        p, s = opt.update(g, s, p)
        return p, s, l
    losses = []
    for i in range(25):
        b = synthetic_mesh_batch(i, 4, 32, 2, out_hw=8)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        p, s, l = step(p, s, b)
        losses.append(float(l))
    assert losses[-1] < losses[0]
