"""Plan-aware tracing & attribution (core.trace, plan.attribution_report).

Single-device half here: StepTrace schema round-trip, Chrome-trace export,
annotation wrappers (identity on values, layer-qualified region names),
the attribution join against a compile_plan'd prediction, and the timing
helpers' new sample-returning surface.  The 4-device segmented-profiler
acceptance (every layer attributed, sums vs whole step, annotations in
compiled HLO) lives in tests/dist_checks.py group 'trace'.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_dist_group
from repro.core import trace as trace_lib
from repro.core.distribution import Dist
from repro.core.perfmodel import TPU_V5E
from repro.core.plan import PlanError, compile_plan
from repro.core.trace import StepTrace, format_attribution
from repro.models.cnn import meshnet
from repro.utils import interleaved_samples, percentile, time_fn

MS22 = {"data": 2, "model": 2}


def _trace(layers, fwd=1e-3, bwd=2e-3):
    rows = {n: {"fwd_s": fwd, "bwd_s": bwd, "fwd_bwd_s": fwd + bwd}
            for n in layers}
    step = {"fwd_s": fwd * len(layers), "bwd_s": bwd * len(layers),
            "fwd_bwd_s": (fwd + bwd) * len(layers)}
    return StepTrace(layers=rows, step=step, meta={"backend": "test"})


# ------------------------------------------------------------ StepTrace --
def test_steptrace_roundtrip(tmp_path):
    t = _trace(["conv1_1", "pred"])
    assert StepTrace.from_dict(t.to_dict()).to_dict() == t.to_dict()
    p = tmp_path / "trace.json"
    t.save(str(p))
    t2 = StepTrace.load(str(p))
    assert t2.layers == t.layers and t2.step == t.step
    assert t2.schema == trace_lib.SCHEMA


def test_steptrace_rejects_wrong_schema():
    with pytest.raises(ValueError, match="not a step trace"):
        StepTrace.from_dict({"schema": "something/else@9", "layers": {},
                             "step": {}})


def test_steptrace_sums():
    t = _trace(["a", "b", "c"], fwd=1.0, bwd=3.0)
    assert t.layer_fwd_sum_s == pytest.approx(3.0)
    assert t.layer_bwd_sum_s == pytest.approx(9.0)
    assert t.layer_sum_s == pytest.approx(12.0)


def test_chrome_trace_export(tmp_path):
    t = _trace(["conv1_1", "conv2_1", "pred"])
    ct = t.chrome_trace()
    assert "traceEvents" in ct and ct["displayTimeUnit"] == "ms"
    xs = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    # one fwd + one bwd slice per layer, all with non-negative ts/dur
    assert len(xs) == 2 * len(t.layers)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    fwd = [e for e in xs if e["cat"] == "fwd"]
    bwd = [e for e in xs if e["cat"] == "bwd"]
    assert [e["name"] for e in fwd] == ["conv1_1", "conv2_1", "pred"]
    assert [e["name"] for e in bwd] == ["pred", "conv2_1", "conv1_1"]
    # the export is valid JSON on disk
    p = tmp_path / "trace.chrome.json"
    t.save_chrome(str(p))
    with open(p) as f:
        assert json.load(f)["traceEvents"]


# ----------------------------------------------------------- annotation --
def test_annotate_identity_on_values():
    def f(x):
        with trace_lib.layer_context("conv9_9"):
            with trace_lib.annotate("halo_exchange"):
                return x * 2 + 1

    x = jnp.arange(6.0)
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x * 2 + 1))
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(x * 2 + 1))
    g = jax.grad(lambda x: jnp.sum(f(x)))(x)
    np.testing.assert_array_equal(np.asarray(g), np.full(6, 2.0))


def test_layer_context_qualifies_regions():
    assert trace_lib.current_layer() is None
    assert trace_lib.qualified("reshard") == "reshard"
    with trace_lib.layer_context("conv2_1"):
        assert trace_lib.current_layer() == "conv2_1"
        assert trace_lib.qualified("reshard") == "conv2_1/reshard"
        with trace_lib.layer_context("inner"):
            assert trace_lib.qualified("x") == "inner/x"
    assert trace_lib.current_layer() is None


def test_layer_names_in_compiled_hlo():
    """layer_context names survive into the compiled HLO op_name metadata
    (single device; the distributed variant is dist_checks 'trace')."""
    cfg = meshnet.MeshNetConfig("t", input_hw=16, in_channels=4,
                                convs_per_block=1, widths=(8,))
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, 16, 16, 4))
    txt = jax.jit(lambda p, x: meshnet.apply(p, x, cfg)) \
        .lower(params, x).compile().as_text()
    for name in meshnet.layer_names(cfg):
        assert name in txt, f"{name!r} missing from compiled HLO"


# ---------------------------------------------------------- attribution --
def _compiled_plan():
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16))
    specs = meshnet.layer_specs(cfg, 4)
    hybrid = Dist("hybrid", {"N": ("data",), "H": ("model",)})
    sample = Dist("sample", {"N": ("data", "model")})
    plan = compile_plan({"conv1_1": hybrid, "conv2_1": sample,
                         "pred": hybrid}, specs, MS22, machine=TPU_V5E)
    return plan, [l.name for l in specs]


def test_attribution_covers_every_layer():
    plan, names = _compiled_plan()
    assert set(plan.predicted["layer_costs"]) == set(names)
    rep = plan.attribution_report(_trace(names))
    assert set(rep["per_layer"]) == set(names)
    assert rep["schema"] == "repro/attribution@1"
    for r in rep["per_layer"].values():
        assert r["predicted_fwd_s"] > 0
        assert r["measured_fwd_s"] == pytest.approx(1e-3)
        assert isinstance(r["flagged"], bool)
    # the report is json-clean as-is (no numpy scalars)
    json.dumps(rep)
    # per-term drift names a worst term from the emitted set
    assert rep["worst_term"] in rep["terms"]
    for t in rep["terms"].values():
        assert t["drift"] > 0 and math.isfinite(t["drift"])
    # the plan charges its two reshard points to the receiving layers
    shuf = plan.predicted["shuffle_per_layer"]
    assert shuf["conv1_1"] == 0.0
    assert shuf["conv2_1"] > 0 and shuf["pred"] > 0


def test_attribution_flags_drifting_layers():
    plan, names = _compiled_plan()
    pred_total = {n: plan.predicted["layer_costs"][n].total for n in names}
    # measured 100x the prediction everywhere -> every layer flagged
    t = _trace(names, fwd=100 * max(pred_total.values()), bwd=0.0)
    rep = plan.attribution_report(t, tol=5.0)
    assert rep["flagged"] == names
    assert rep["totals"]["ratio"] > 5.0
    out = format_attribution(rep)
    assert "<-- drift" in out and "worst:" in out


def test_attribution_requires_predictions_and_full_trace():
    plan, names = _compiled_plan()
    import dataclasses
    bare = dataclasses.replace(plan, predicted=None)
    with pytest.raises(PlanError, match="machine"):
        bare.attribution_report(_trace(names))
    with pytest.raises(PlanError, match="no measurement"):
        plan.attribution_report(_trace(names[:-1]))


# -------------------------------------------------------------- timing --
def test_time_fn_return_samples():
    est = time_fn(lambda: jnp.zeros(4), reps=2, warmup=1)
    est2, samples = time_fn(lambda: jnp.zeros(4), reps=3, warmup=1,
                            return_samples=True)
    assert est > 0 and est2 > 0
    assert len(samples) == 3 and all(s > 0 for s in samples)


def test_interleaved_samples_and_percentile():
    fns = {"a": lambda: jnp.zeros(2), "b": lambda: jnp.zeros(2)}
    for f in fns.values():
        f()
    samples = interleaved_samples(fns, reps=2, rounds=3)
    assert set(samples) == {"a", "b"}
    assert all(len(s) == 3 for s in samples.values())
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 95) == pytest.approx(3.85)
    assert math.isnan(percentile([], 50))


# ------------------------------------------------------------ 4-device --
def test_trace_distributed():
    """4-device segmented profiler acceptance: every solved-plan layer
    attributed with measured fwd+bwd, per-layer sums within tolerance of
    the whole fused step, attribution join complete, annotations present
    in the compiled HLO (dist_checks group 'trace'; fast — run by the CI
    fast lane like 'cf')."""
    run_dist_group("trace")
