"""Strategy-to-execution plan compiler (core.plan) tests.

Single-device half here; the 4-device uniform-vs-auto agreement check lives
in tests/dist_checks.py group 'plan' (subprocess, 8 host devices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_dist_group
from repro.core.channel_conv import CFSharding
from repro.core.distribution import Dist, channel_filter
from repro.core.perfmodel import ConvLayer, LASSEN, TPU_V5E
from repro.core.plan import (NetworkPlan, PlanError, compile_plan,
                             dist_to_sharding, executable_candidates,
                             normalize_dist, plan_graph, plan_line)
from repro.core.spatial_conv import ConvSharding
from repro.data.pipeline import synthetic_mesh_batch
from repro.launch.mesh import make_mesh
from repro.models.cnn import meshnet, resnet

MS22 = {"data": 2, "model": 2}
MS222 = {"pod": 2, "data": 2, "model": 2}


# ------------------------------------------------------------- lowering --
def test_dist_to_sharding_basic():
    d = Dist("hybrid", {"N": ("data",), "H": ("model",)})
    sh = dist_to_sharding(d, MS22)
    assert sh == ConvSharding(batch_axes=("data",), h_axis="model")
    d = Dist("spatial2d", {"H": ("model",), "W": ("data",)})
    sh = dist_to_sharding(d, MS22)
    assert sh == ConvSharding(batch_axes=(), h_axis="model", w_axis="data")


def test_dist_to_sharding_lowers_channel_filter():
    """CF dists (§III-D) lower to CFSharding — no longer perf-model-only."""
    sh = dist_to_sharding(Dist("cf", {"N": ("data",), "C": ("model",),
                                      "F": ("model",)}), MS22)
    assert sh == CFSharding(batch_axes=("data",), cf_axis="model")
    sh = dist_to_sharding(channel_filter(), MS22)
    assert sh.cf_axis == "model" and sh.mode == "channel"


def test_dist_to_sharding_lowers_multi_axis_spatial():
    """H (or W) over a *product* of mesh axes lowers to a tuple h_axis —
    the 16x16-mesh decomposition (core.halo product axes)."""
    sh = dist_to_sharding(Dist("s", {"H": ("data", "model")}), MS22)
    assert sh == ConvSharding(h_axis=("data", "model"))
    assert sh.h_axes == ("data", "model") and sh.spatial_axes == sh.h_axes
    sh = dist_to_sharding(Dist("s", {"N": ("pod",),
                                     "W": ("data", "model")}), MS222)
    assert sh == ConvSharding(batch_axes=("pod",), w_axis=("data", "model"))


def test_dist_to_sharding_lowers_cf_x_spatial():
    """CF on one mesh axis composed with spatial sharding on others lowers
    to a CFSharding carrying h_axis/w_axis (one-shard_map composition)."""
    sh = dist_to_sharding(Dist("cfh", {"H": ("data",), "C": ("model",),
                                       "F": ("model",)}), MS22)
    assert isinstance(sh, CFSharding)
    assert sh.cf_axis == "model" and sh.h_axis == "data"
    assert sh.is_spatial
    # spatial over a product of axes, CF on the third
    sh = dist_to_sharding(Dist("cfh2", {"H": ("pod", "data"),
                                        "C": ("model",), "F": ("model",)}),
                          MS222)
    assert sh.cf_axis == "model" and sh.h_axis == ("pod", "data")


def test_dist_to_sharding_rejects_non_executable():
    with pytest.raises(PlanError):   # non-CNN dim
        dist_to_sharding(Dist("seq", {"N": ("data",), "S": ("model",)}),
                         MS22)
    with pytest.raises(PlanError):   # C and F on different axes
        dist_to_sharding(Dist("cx", {"C": ("model",), "F": ("data",)}),
                         MS22)
    with pytest.raises(PlanError):   # multi-axis CF group
        dist_to_sharding(Dist("c2", {"C": ("data", "model"),
                                     "F": ("data", "model")}), MS22)
    with pytest.raises(PlanError):   # CF and spatial on the SAME axis
        dist_to_sharding(Dist("clash", {"H": ("model",), "C": ("model",),
                                        "F": ("model",)}), MS22)


def test_plan_error_names_layer_and_suggests_demotion():
    """PlanError diagnostics: the offending layer and dist are named and
    the nearest executable demotion is suggested."""
    with pytest.raises(PlanError, match=r"layer 'res9'.*nearest executable"):
        dist_to_sharding(Dist("cx", {"C": ("model",), "F": ("data",)}),
                         MS22, layer="res9")
    with pytest.raises(PlanError, match=r"demot"):
        dist_to_sharding(Dist("c2", {"C": ("data", "model"),
                                     "F": ("data", "model")}), MS22)
    # compile_plan names the layer for the indivisible-batch case too
    specs = [ConvLayer("odd", n=3, c=4, h=32, w=32, f=8, k=3, s=1)]
    with pytest.raises(PlanError, match=r"layer 'odd'.*nearest executable"):
        compile_plan({"odd": Dist("sample", {"N": ("data", "model")})},
                     specs, MS22)


def test_normalize_drops_size1_axes():
    ms = {"data": 1, "model": 1}
    d = normalize_dist(Dist("hybrid", {"N": ("data",), "H": ("model",)}), ms)
    assert d.dims == {}
    # and the lowered sharding takes the dense single-device path
    assert dist_to_sharding(d, ms) == ConvSharding()


def test_executable_candidates_never_empty():
    # N=2 on a 4-way mesh, spatial shards smaller than the kernel: nothing
    # parallel fits -> the replicated fallback keeps the solver total
    layer = ConvLayer("tiny", n=2, c=8, h=4, w=4, f=8, k=3, s=1)
    cands = executable_candidates(layer, {"data": 2, "model": 2})
    assert cands, "fallback missing"
    assert all(dist_to_sharding(d, MS22) is not None for d in cands)


# ----------------------------------------------------------- compilation --
def test_compile_plan_reshard_points_and_cost():
    specs = [ConvLayer("a", n=8, c=4, h=32, w=32, f=8, k=3, s=1),
             ConvLayer("b", n=8, c=8, h=32, w=32, f=8, k=3, s=1),
             ConvLayer("c", n=8, c=8, h=32, w=32, f=8, k=3, s=1)]
    dists = {"a": Dist("hybrid", {"N": ("data",), "H": ("model",)}),
             "b": Dist("sample", {"N": ("data", "model")}),
             "c": Dist("sample", {"N": ("data", "model")})}
    plan = compile_plan(dists, specs, MS22, machine=LASSEN)
    assert not plan.layers["a"].reshard_in
    assert plan.layers["b"].reshard_in      # hybrid -> sample: §III-C shuffle
    assert not plan.layers["c"].reshard_in  # same dist: free
    assert plan.n_reshards == 1
    assert plan.predicted is not None and plan.predicted["shuffle"] > 0


def test_compile_plan_demotes_unfit_geometry():
    # H=4 over 2-way model with k=3: shard (2 rows) < kernel -> demoted at
    # compile time (the ConvSharding.fit edge case), recorded in the note
    specs = [ConvLayer("a", n=8, c=4, h=4, w=4, f=8, k=3, s=1)]
    dists = {"a": Dist("hybrid", {"N": ("data",), "H": ("model",)})}
    plan = compile_plan(dists, specs, MS22)
    lp = plan.layers["a"]
    assert lp.sharding.h_axis is None
    assert "demoted" in lp.note


def test_compile_plan_demotes_nondivisible_channels():
    """CF edge case: channel counts that don't divide the CF mesh axis are
    demoted to the sample-parallel remainder at compile time, recorded."""
    specs = [ConvLayer("a", n=8, c=5, h=8, w=8, f=8, k=3, s=1),   # C=5 % 2
             ConvLayer("b", n=8, c=8, h=8, w=8, f=7, k=3, s=1)]   # F=7 % 2
    dists = {"a": Dist("cf", {"N": ("data",), "C": ("model",),
                              "F": ("model",)}),
             "b": Dist("cf", {"N": ("data",), "C": ("model",),
                              "F": ("model",)})}
    plan = compile_plan(dists, specs, MS22, machine=LASSEN)
    for name in ("a", "b"):
        lp = plan.layers[name]
        assert lp.sharding == ConvSharding(batch_axes=("data",))
        assert "demoted C/F" in lp.note
    # the cost report is computed under the demoted (executed) dists
    assert plan.predicted is not None
    # divisible channels survive as CFSharding (mode solved per layer from
    # the AG(x)-vs-RS(y) payloads: F = 2C at stride 1 -> 'filter')
    specs[0] = ConvLayer("a", n=8, c=4, h=8, w=8, f=8, k=3, s=1)
    plan = compile_plan({"a": dists["a"]}, specs[:1], MS22)
    assert plan.layers["a"].sharding == CFSharding(batch_axes=("data",),
                                                   cf_axis="model",
                                                   mode="filter")
    assert not plan.layers["a"].note


def test_cf_candidates_executable_and_solver_uses_them():
    """A layer whose spatial extent is below the kernel but whose channels
    divide the mesh gets CF candidates; with CF disabled it falls back to
    replicated."""
    layer = ConvLayer("late", n=2, c=32, h=4, w=4, f=32, k=3, s=1)
    cands = executable_candidates(layer, MS22)
    assert any(d.axes("C") for d in cands), [d.name for d in cands]
    nocf = executable_candidates(layer, MS22, allow_channel_filter=False)
    assert not any(d.axes("C") for d in nocf)


def test_every_executable_candidate_lowers():
    """Property: every dist `executable_candidates` emits survives
    `dist_to_sharding` without PlanError — the solver-side filter and the
    runtime lowering must not drift (now including multi-axis spatial and
    CF x spatial dists on 3-axis meshes)."""
    meshes = [MS22, MS222, {"data": 4, "model": 2}, {"data": 2}]
    layers = [
        ConvLayer("big", n=8, c=16, h=64, w=64, f=32, k=3, s=1),
        ConvLayer("strided", n=4, c=8, h=32, w=32, f=16, k=3, s=2),
        ConvLayer("late", n=2, c=32, h=8, w=8, f=64, k=3, s=1),
        ConvLayer("tiny", n=2, c=32, h=4, w=4, f=32, k=3, s=1),
        ConvLayer("pool", n=8, c=16, h=32, w=32, f=16, k=3, s=2,
                  kind="pool"),
        ConvLayer("pred", n=2, c=64, h=8, w=8, f=1, k=1, s=1),
    ]
    n_multi = n_cfsp = 0
    for ms in meshes:
        for layer in layers:
            for d in executable_candidates(layer, ms):
                sh = dist_to_sharding(d, ms, layer=layer.name)  # must not raise
                assert sh is not None
                if len(d.axes("H")) > 1 or len(d.axes("W")) > 1:
                    n_multi += 1
                if d.axes("C") and (d.axes("H") or d.axes("W")):
                    n_cfsp += 1
    # the new hybrid families must actually appear in the candidate sets
    assert n_multi > 0, "no multi-axis spatial candidate emitted"
    assert n_cfsp > 0, "no CF x spatial candidate emitted"


def test_solver_picks_cf_mode_from_collective_sizes():
    """The compiled mode per CF layer is 'filter' iff AG(x) moves fewer
    words than RS(y) — and the chosen mode's collective is the smaller one
    (ROADMAP PR-2 leftover: no more blind 'channel')."""
    from repro.core.perfmodel import cf_collective_words, cf_mode_for
    cf = Dist("cf", {"N": ("data",), "C": ("model",), "F": ("model",)})
    # F >> C at stride 1: RS(y) is the bigger payload -> 'filter'
    grow = ConvLayer("grow", n=4, c=8, h=8, w=8, f=64, k=3, s=1)
    # C >> F: AG(x) is the bigger payload -> 'channel'
    shrink = ConvLayer("shrink", n=4, c=64, h=8, w=8, f=8, k=3, s=1)
    for spec, want in ((grow, "filter"), (shrink, "channel")):
        assert cf_mode_for(spec, cf, MS22) == want
        words = cf_collective_words(spec, cf, MS22)
        chosen = words["ag_x"] if want == "filter" else words["rs_y"]
        assert chosen == min(words["ag_x"], words["rs_y"])
        plan = compile_plan({spec.name: cf}, [spec], MS22)
        sh = plan.layers[spec.name].sharding
        assert isinstance(sh, CFSharding) and sh.mode == want
    # the mode pick accounts for composed spatial splits (local payloads)
    cfh = Dist("cfh", {"H": ("data",), "C": ("model",), "F": ("model",)})
    plan = compile_plan({"grow": cfh}, [grow], MS22)
    assert plan.layers["grow"].sharding.mode == \
        cf_mode_for(grow, cfh, MS22) == "filter"


def test_compile_plan_rejects_indivisible_batch():
    specs = [ConvLayer("a", n=3, c=4, h=32, w=32, f=8, k=3, s=1)]
    dists = {"a": Dist("sample", {"N": ("data", "model")})}
    with pytest.raises(PlanError):
        compile_plan(dists, specs, MS22)


def test_plan_graph_covers_all_resnet_layers():
    cfg = resnet.ResNetConfig(name="tiny", input_hw=32, n_classes=10,
                              stages=(1, 1), widths=(8, 16))
    g = resnet.resnet_graph(8, cfg)
    specs = resnet.layer_specs(8, cfg)
    plan = plan_graph(TPU_V5E, g, specs, MS22)
    assert set(g.nodes) <= set(plan.layers)
    assert plan.predicted is not None
    txt = plan.describe()
    for name in g.nodes:
        assert name in txt


def test_uniform_plan_answers_any_layer():
    sh = ConvSharding(batch_axes=("data",), h_axis="model")
    plan = NetworkPlan.uniform(sh)
    assert plan.sharding("anything") == sh
    assert plan.n_reshards == 0
    strict = NetworkPlan.from_shardings(["a"], [sh])
    with pytest.raises(PlanError):
        strict.sharding("unknown")


# ------------------------------------------------- execution equivalence --
CFG = meshnet.MeshNetConfig("t", input_hw=32, in_channels=2,
                            convs_per_block=1, widths=(4, 8))


def _batch():
    return {k: jnp.asarray(v) for k, v in
            synthetic_mesh_batch(0, 4, 32, 2, out_hw=8).items()}


def _loss_and_grads(plan, mesh):
    params = meshnet.init(jax.random.PRNGKey(0), CFG)
    f = jax.jit(lambda p, b: meshnet.loss_fn(p, b, CFG, plan, mesh))
    g = jax.jit(jax.grad(lambda p, b: meshnet.loss_fn(p, b, CFG, plan,
                                                      mesh)))
    b = _batch()
    return f(params, b), g(params, b)


def test_uniform_plan_matches_legacy_sharding_bitwise():
    """NetworkPlan.uniform(sh) reproduces the seed's single-ConvSharding
    numerics bit for bit (backward compatibility contract)."""
    l_ref, g_ref = _loss_and_grads(ConvSharding(), None)
    plan = NetworkPlan.uniform(ConvSharding(),
                               meshnet.layer_names(CFG))
    l_got, g_got = _loss_and_grads(plan, None)
    np.testing.assert_array_equal(np.asarray(l_got), np.asarray(l_ref))
    for a, b in zip(jax.tree.leaves(g_got), jax.tree.leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_auto_plan_1x1_mesh_matches_oracle_bitwise():
    """A solved plan on a 1x1 mesh normalizes to the dense path and matches
    the single-device oracle bit for bit."""
    mesh = make_mesh(data=1, model=1)
    specs = meshnet.layer_specs(CFG, 4)
    plan = plan_line(TPU_V5E, specs, mesh)
    for lp in plan.layers.values():     # size-1 axes all dropped
        assert lp.sharding == ConvSharding()
        assert not lp.reshard_in
    l_ref, g_ref = _loss_and_grads(ConvSharding(), None)
    l_got, g_got = _loss_and_grads(plan, mesh)
    np.testing.assert_array_equal(np.asarray(l_got), np.asarray(l_ref))
    for a, b in zip(jax.tree.leaves(g_got), jax.tree.leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cf_plan_1x1_mesh_matches_oracle_bitwise():
    """A plan whose dists are channel_filter() everywhere, compiled on a
    1x1 mesh, normalizes to the dense single-device path: the CF lowering
    must be bitwise-invisible there (the oracle-equivalence contract)."""
    mesh = make_mesh(data=1, model=1)
    specs = meshnet.layer_specs(CFG, 4)
    dists = {l.name: channel_filter() for l in specs}
    plan = compile_plan(dists, specs, mesh)
    for lp in plan.layers.values():     # size-1 axes all dropped
        assert lp.sharding == ConvSharding()
        assert not lp.reshard_in
    l_ref, g_ref = _loss_and_grads(ConvSharding(), None)
    l_got, g_got = _loss_and_grads(plan, mesh)
    np.testing.assert_array_equal(np.asarray(l_got), np.asarray(l_ref))
    for a, b in zip(jax.tree.leaves(g_got), jax.tree.leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resnet_uniform_plan_matches_legacy_bitwise():
    cfg = resnet.ResNetConfig(name="tiny", input_hw=32, n_classes=10,
                              stages=(1, 1), widths=(4, 8))
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    ref = resnet.apply(params, x, cfg, ConvSharding())
    plan = NetworkPlan.uniform(ConvSharding(),
                               [l.name for l in resnet.layer_specs(2, cfg)])
    got = resnet.apply(params, x, cfg, plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ------------------------------------------------------------ 4-device --
@pytest.mark.slow
def test_plan_distributed():
    """Solved auto plan vs uniform plan vs single-device oracle on a 2x2
    mesh (subprocess; numeric agreement for loss and grads)."""
    run_dist_group("plan")


def test_plan_cf_distributed():
    """4-device uniform-vs-CF agreement: the solved plan contains >= 1 CF
    layer and matches the oracle (dist_checks group 'cf'; fast — also run
    by the CI fast lane)."""
    run_dist_group("cf")


@pytest.mark.slow
def test_plan_spatial2d_distributed():
    """W-axis and 2-D (H x W) spatial decompositions through conv/pool and
    a compiled W-split plan (dist_checks group 'spatial2d')."""
    run_dist_group("spatial2d")


def test_plan_multiaxis_distributed():
    """8-device (2,2,2) mesh: product-axis halo conv/pool, CF x spatial
    composition (both modes), the Pallas backend in interpret mode, and a
    solved auto plan with >= 1 multi-axis-H layer and >= 1 CF x spatial
    layer vs the single-device oracle (dist_checks group 'multiaxis';
    fast — run by the CI fast lane like 'cf')."""
    run_dist_group("multiaxis")


def test_plan_overlap_distributed():
    """4-device §IV-A latency-hiding schedule: interior/boundary split
    parity (fwd + grads) vs the serialized path and the oracle on the XLA
    and Pallas-interpret backends, plus the optimization_barrier pin
    surviving jit lowering (dist_checks group 'overlap'; fast — run by
    the CI fast lane like 'cf')."""
    run_dist_group("overlap")


def test_plan_memfit_distributed():
    """4-device memory-aware planning acceptance (paper §VI Table 2): a
    synthetic per-device capacity limit rules uniform sample-parallel out;
    the --mem-limit solve returns a spatial plan whose modeled peak fits,
    whose XLA-measured peak agrees within the 2x property tolerance, and
    which executes fwd+bwd matching the single-device oracle (dist_checks
    group 'memfit'; fast — run by the CI fast lane like 'cf')."""
    run_dist_group("memfit")
