"""Performance model (paper §V) + strategy optimizer (§V-C) tests."""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import perfmodel as pm
from repro.core import strategy as strat
from repro.core.distribution import Dist, hybrid, sample
from repro.models.cnn import meshnet, resnet

M = dataclasses.replace(pm.LASSEN, compute_efficiency=0.119,
                        eff_halfwork=1.49e9)


def test_collective_models_sane():
    # allreduce cost grows with message size and is >= 0
    assert pm.allreduce_time(M, 4, 1 << 20) > pm.allreduce_time(M, 4, 1 << 10)
    assert pm.allreduce_time(M, 1, 1 << 20) == 0.0
    # ring beats recursive doubling for large messages (Thakur)
    big = 64 << 20
    ring = 2 * 63 * M.alpha_coll + 2 * 63 / 64 * big * M.beta_coll
    assert pm.allreduce_time(M, 64, big) <= ring + 1e-12
    assert pm.sr_time(M, 0) == 0.0
    assert pm.all_to_all_time(M, 8, 1 << 20) > 0


def test_layer_cost_sample_cheapest_comm():
    """Paper: 'sample parallelism is the cheapest approach: it requires
    only the allreduce time in BPa'."""
    layer = pm.ConvLayer("c", n=32, c=64, h=56, w=56, f=64, k=3, s=1)
    ms = {"data": 2, "model": 2}
    cs = pm.layer_cost(M, layer, sample(("data", "model")), ms,
                       overlap=False)
    ch = pm.layer_cost(M, layer, hybrid(("data",), ("model",)), ms,
                       overlap=False)
    # same compute split, but hybrid adds halo time
    comm_h = ch.total - ch.fp_compute - ch.bp_compute
    assert comm_h > cs.bpa * 0.99


def test_overlap_reduces_cost():
    layer = pm.ConvLayer("c", n=4, c=64, h=1024, w=1024, f=64, k=3, s=1)
    ms = {"model": 4}
    d = Dist("h", {"H": ("model",)})
    c_ov = pm.layer_cost(M, layer, d, ms, overlap=True)
    c_no = pm.layer_cost(M, layer, d, ms, overlap=False)
    assert c_ov.total <= c_no.total


def test_product_axis_halo_hop_pricing():
    """Halo over a product of mesh axes (H split 2x2 ways) pays extra link
    hops on its boundary-crossing sends but sends fewer messages than the
    H x W decomposition at the same total bytes (no corner exchanges):
    dearer than a true single-axis split, cheaper than H x W on squares."""
    assert pm.sr_time(M, 1024, hops=2) > pm.sr_time(M, 1024)
    assert pm.sr_time(M, 1024, hops=2) == M.alpha * 2 + M.beta * 1024
    layer = pm.ConvLayer("c", n=2, c=16, h=64, w=64, f=16, k=3, s=1)
    ms = {"a": 2, "b": 2}
    comm = lambda c: c.fp - c.fp_compute    # noqa: E731
    c_prod = pm.layer_cost(M, layer, Dist("hh", {"H": ("a", "b")}), ms,
                           overlap=False)
    c_hw = pm.layer_cost(M, layer, Dist("hw", {"H": ("a",), "W": ("b",)}),
                         ms, overlap=False)
    c_one = pm.layer_cost(M, layer, Dist("h4", {"H": ("a",)}), {"a": 4},
                          overlap=False)
    assert c_prod.fp_compute == c_hw.fp_compute == c_one.fp_compute
    assert comm(c_one) < comm(c_prod) < comm(c_hw)


def test_cf_overlap_credit_matches_runtime_semantics():
    """The model's CF forward term credits overlap η-scaled:
    fp = compute + RS - η·min(RS, compute).  At the analytic machines'
    η=1 default that is exactly max(compute, RS) — justified now that
    channel_conv's overlapped channel mode pipelines the psum_scatter
    with per-channel-block compute (§IV-A analogue) — while a calibrated
    η < 1 keeps the unhidden share of the collective on the bill."""
    layer = pm.ConvLayer("cf", n=4, c=32, h=8, w=8, f=32, k=3, s=1)
    ms = {"data": 2, "model": 2}
    cf = Dist("cf", {"N": ("data",), "C": ("model",), "F": ("model",)})
    ov = pm.layer_cost(M, layer, cf, ms, overlap=True)
    no = pm.layer_cost(M, layer, cf, ms, overlap=False)
    rs = no.fp - no.fp_compute
    assert rs > 0, "CF layer must pay a forward reduce-scatter"
    assert M.overlap_eta == 1.0       # analytic machines stay at full credit
    assert ov.fp == max(ov.fp_compute, rs)
    assert ov.fp_saved == pytest.approx(min(rs, ov.fp_compute))
    assert ov.total <= no.total
    # η = 0.5: exactly half of the hideable min is credited, and the saved
    # seconds are surfaced per layer via LayerCost.overlap_credit
    M5 = dataclasses.replace(M, overlap_eta=0.5)
    half = pm.layer_cost(M5, layer, cf, ms, overlap=True)
    assert half.fp == pytest.approx(
        half.fp_compute + rs - 0.5 * min(rs, half.fp_compute))
    assert half.fp_saved == pytest.approx(0.5 * min(rs, half.fp_compute))
    assert no.fp_saved == no.bp_saved == 0.0 and no.overlap_credit == 0.0
    assert ov.fp < half.fp < no.fp
    # η = 0 degenerates to the serialized bill even with overlap=True
    z = pm.layer_cost(dataclasses.replace(M, overlap_eta=0.0), layer, cf,
                      ms, overlap=True)
    assert z.fp == no.fp and z.overlap_credit == 0.0


def test_cf_collective_words_at_submesh_sizes():
    """AG(x)/RS(y) payloads shrink with composed spatial splits and the
    collective runs at the CF sub-mesh size, not the whole mesh."""
    layer = pm.ConvLayer("cf", n=4, c=16, h=16, w=16, f=32, k=3, s=1)
    ms = {"pod": 2, "data": 2, "model": 2}
    pure = Dist("cf", {"N": ("pod", "data"), "C": ("model",),
                       "F": ("model",)})
    comp = Dist("cfh", {"N": ("pod",), "H": ("data",), "C": ("model",),
                        "F": ("model",)})
    wp = pm.cf_collective_words(layer, pure, ms)
    wc = pm.cf_collective_words(layer, comp, ms)
    assert wp["p_cf"] == wc["p_cf"] == 2          # sub-mesh, not 8
    assert wc["rs_y"] == wp["rs_y"]               # n doubles, H halves
    assert pm.cf_mode_for(layer, pure, ms) == "filter"   # F=2C at s=1


def test_candidates_valid():
    layer = pm.ConvLayer("c", n=6, c=18, h=96, w=96, f=64, k=3, s=2)
    ms = {"data": 3, "model": 2}
    cands = strat.candidate_dists(layer, ms, allow_channel_filter=True)
    assert cands, "must generate at least one candidate"
    for d in cands:
        for dim, size in [("N", layer.n), ("H", layer.h), ("W", layer.w),
                          ("C", layer.c), ("F", layer.f)]:
            assert size % d.ways(dim, ms) == 0
        if d.ways("H", ms) > 1:
            assert layer.h // d.ways("H", ms) >= layer.k


@settings(max_examples=20, deadline=None)
@given(n_layers=st.integers(2, 5), seed=st.integers(0, 100))
def test_line_solver_optimal(n_layers, seed):
    """DP shortest path == brute force on small strategy spaces."""
    ms = {"data": 2, "model": 2}
    layers = [pm.ConvLayer(f"l{i}", n=4, c=8, h=32, w=32, f=8, k=3, s=1)
              for i in range(n_layers)]
    cands = [strat.candidate_dists(l, ms) for l in layers]
    res = strat.solve_line(M, layers, cands, ms)
    # brute force
    import itertools
    best = np.inf
    for combo in itertools.product(*cands):
        c = sum(pm.layer_cost(M, l, d, ms).total
                for l, d in zip(layers, combo))
        c += sum(pm.shuffle_time(M, layers[i], combo[i], combo[i + 1], ms)
                 for i in range(n_layers - 1))
        best = min(best, c)
    assert res.cost <= best * (1 + 1e-9)


def test_dag_solver_covers_resnet():
    g = resnet.resnet_graph(32)
    sol = strat.solve_dag(M, g, {"data": 2, "model": 2})
    assert set(sol) == set(g.nodes)


def test_paper_conclusions():
    """Strategy engine reproduces the paper's qualitative findings:
    spatial wins for large-spatial mesh layers, sample for ResNet."""
    ms = {"data": 4, "model": 4}
    mesh_layers = meshnet.layer_specs(meshnet.MESH1K, 4)
    cands = [strat.candidate_dists(l, ms) for l in mesh_layers]
    res = strat.solve_line(M, mesh_layers, cands, ms)
    assert any(d.ways("H", ms) > 1 for d in res.dists), \
        "mesh model should use spatial parallelism"
    rn = resnet.layer_specs(256)
    cands = [strat.candidate_dists(l, ms) for l in rn]
    res_rn = strat.solve_line(M, rn, cands, ms)
    n_sample = sum(d.ways("N", ms) == 16 for d in res_rn.dists)
    assert n_sample > len(rn) * 0.6, \
        "ResNet at large batch should be mostly sample-parallel"


def test_table1_reproduction():
    """Perf model reproduces paper Table I (1K mesh strong scaling) within
    tolerance after the 2-constant calibration (EXPERIMENTS.md §Paper)."""
    SPLITS = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2), 16: (4, 4)}
    TABLE1 = {4: {1: 0.403, 2: 0.2, 4: 0.121, 8: 0.0906, 16: 0.066},
              32: {1: 0.401, 2: 0.207, 4: 0.123, 8: 0.0874, 16: 0.0794}}
    errs = []
    for N, row in TABLE1.items():
        for p, t in row.items():
            hy, wx = SPLITS[p]
            ms = {"d": N, "mh": hy, "mw": wx}
            dims = {"N": ("d",)}
            if hy > 1:
                dims["H"] = ("mh",)
            if wx > 1:
                dims["W"] = ("mw",)
            d = Dist(f"hyb{p}", dims)
            layers = meshnet.layer_specs(meshnet.MESH1K, N)
            pred = pm.network_cost(M, layers, [d] * len(layers), ms)["total"]
            errs.append(abs(pred / t - 1))
    assert np.mean(errs) < 0.10, f"mean error {np.mean(errs):.1%}"
    assert np.max(errs) < 0.25, f"max error {np.max(errs):.1%}"
