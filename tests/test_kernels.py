"""Pallas kernel correctness: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (+ hypothesis-generated cases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.conv2d import conv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd_chunk
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- conv2d --
@pytest.mark.parametrize("h,w,c,f,k,s", [
    (18, 16, 8, 16, 3, 1), (33, 16, 4, 8, 3, 2), (16, 12, 3, 5, 1, 1),
    (23, 9, 6, 128, 7, 2), (12, 8, 16, 256, 3, 1), (9, 9, 2, 3, 5, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_sweep(h, w, c, f, k, s, dtype):
    x = jax.random.normal(KEY, (2, h, w, c), dtype)
    wt = (jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f), dtype)
          * 0.1).astype(dtype)
    got = conv2d(x, wt, stride=s, interpret=True)
    want = ref.conv2d_ref(x, wt, stride=s)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(h=st.integers(5, 24), w=st.integers(5, 16), c=st.integers(1, 8),
       f=st.integers(1, 16), k=st.sampled_from([1, 3, 5]),
       s=st.sampled_from([1, 2]))
def test_conv2d_property(h, w, c, f, k, s):
    if h < k or w < k:
        return
    x = jax.random.normal(KEY, (1, h, w, c), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f)) * 0.1
    got = conv2d(x, wt, stride=s, interpret=True)
    want = ref.conv2d_ref(x, wt, stride=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("h,w,c,f,k,s", [
    (16, 16, 4, 8, 3, 1), (32, 16, 3, 8, 7, 2), (16, 8, 4, 4, 1, 1),
    (16, 16, 6, 6, 3, 2),
])
def test_spatial_conv2d_pallas_backend_parity(h, w, c, f, k, s):
    """backend='pallas' routes the local conv through the implicit-GEMM
    kernel (interpret mode off-TPU) and matches the XLA lowering of the
    same 'SAME'-padded conv."""
    from repro.core.spatial_conv import ConvSharding, spatial_conv2d
    x = jax.random.normal(KEY, (2, h, w, c), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f)) * 0.1
    sh = ConvSharding()          # local path: the kernel under test
    want = spatial_conv2d(x, wt, strides=(s, s), sharding=sh, backend="xla")
    got = spatial_conv2d(x, wt, strides=(s, s), sharding=sh,
                         backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # via the layer API (geometry fit + stride plumbing)
    from repro.models.cnn import layers as L
    got2 = L.conv_apply({"w": wt}, x, stride=s, sharding=sh,
                        backend="pallas")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ------------------------------------------------------- flash attention --
@pytest.mark.parametrize("sq,hq,hkv,d,causal,window,cap", [
    (64, 4, 2, 32, True, None, None), (128, 8, 8, 16, True, 37, None),
    (64, 4, 1, 64, False, None, None), (96, 6, 3, 32, True, None, 30.0),
    (32, 2, 2, 8, True, 5, 20.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(sq, hq, hkv, d, causal, window, cap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (2, sq, hkv, d), dtype)
    v = jax.random.normal(ks[2], (2, sq, hkv, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([16, 32, 48]), g=st.sampled_from([1, 2, 4]),
       hkv=st.integers(1, 3), d=st.sampled_from([8, 16]),
       causal=st.booleans(),
       window=st.one_of(st.none(), st.integers(1, 20)))
def test_flash_property(sq, g, hkv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, sq, hkv * g, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, sq, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, sq, hkv, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # rows of softmax sum to 1 -> output within [min(v), max(v)] hull
    assert np.isfinite(np.asarray(got)).all()


# ------------------------------------------------------------------- ssd --
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (2, 64, 4, 16, 8, 16), (1, 32, 8, 8, 16, 32), (2, 48, 2, 32, 4, 8),
])
def test_ssd_sweep(b, l, h, p, n, chunk):
    ks = jax.random.split(KEY, 4)
    xdt = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    la = -jax.random.uniform(ks[1], (b, l, h), minval=0.01, maxval=0.5)
    B = jax.random.normal(ks[2], (b, l, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, n)) * 0.5
    y, s = ssd_chunk(xdt, la, B, C, chunk=chunk, interpret=True)
    for i in range(l // chunk):
        sl = slice(i * chunk, (i + 1) * chunk)
        yr, sr = ref.ssd_chunk_ref(xdt[:, sl], la[:, sl], B[:, sl],
                                   C[:, sl])
        np.testing.assert_allclose(np.asarray(y[:, sl]), np.asarray(yr),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(s[:, i]), np.asarray(sr),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([16, 32]), h=st.integers(1, 4),
       p=st.sampled_from([4, 8]), n=st.sampled_from([4, 8]))
def test_ssd_property(l, h, p, n):
    ks = jax.random.split(KEY, 4)
    xdt = jax.random.normal(ks[0], (1, l, h, p)) * 0.5
    la = -jax.random.uniform(ks[1], (1, l, h), minval=0.01, maxval=1.0)
    B = jax.random.normal(ks[2], (1, l, n)) * 0.5
    C = jax.random.normal(ks[3], (1, l, n)) * 0.5
    y, s = ssd_chunk(xdt, la, B, C, chunk=l, interpret=True)
    yr, sr = ref.ssd_chunk_ref(xdt, la, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s[:, 0]), np.asarray(sr),
                               rtol=2e-5, atol=2e-5)
