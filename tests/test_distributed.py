"""Distributed-correctness suite (8 host devices, subprocess-isolated).

Each test drives one group in tests/dist_checks.py:
  conv       spatial conv/pool/BN == single-device oracle (fwd + grads,
             1-D and 2-D decomposition, overlap on/off)   [paper §III-A]
  attention  ring / windowed-halo / decode attention == oracle
  ssm        distributed prefix state == sequential scan
  models     per-family sequence-parallel loss+decode == oracle
  train      resilient E2E training (fault injection, int8 EF compression,
             grad accumulation, hybrid parallelism)
  compress   cross-pod gradient compression semantics
"""
import pytest

from conftest import run_dist_group

pytestmark = pytest.mark.slow      # subprocess, 8 host devices


@pytest.mark.parametrize("group", ["conv", "attention", "ssm", "models",
                                   "train", "compress"])
def test_distributed(group):
    run_dist_group(group)
