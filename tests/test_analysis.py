"""Static analysis: plan lint + collective audit (repro.analysis).

The pure-lint rules run here on hand-built plans (single device — lint
never touches a backend).  The collective auditor's property test (every
executable candidate dist lowers and audits clean) and its negative cases
(injected unpriced collective, stripped overlap pin) live in
tests/dist_checks.py group 'audit' (subprocess, 8 host devices).
"""
import dataclasses
import json

import pytest

from conftest import run_dist_group
from repro import analysis
from repro.core import perfmodel as pm
from repro.core import plan as plan_lib
from repro.core.spatial_conv import ConvSharding

MESH = {"data": 2, "model": 2}


def specs3():
    return [pm.ConvLayer("a", n=4, c=8, h=16, w=16, f=8),
            pm.ConvLayer("b", n=4, c=8, h=16, w=16, f=16),
            pm.ConvLayer("c", n=4, c=16, h=16, w=16, f=8)]


def resharding_plan(machine=pm.TPU_V5E):
    """a: batch x H, b/c: H x W — one priced reshard into 'b'."""
    d1 = plan_lib._sharding_to_dist(
        ConvSharding(batch_axes=("data",), h_axis="model"))
    d2 = plan_lib._sharding_to_dist(
        ConvSharding(h_axis="model", w_axis="data"))
    return plan_lib.compile_plan({"a": d1, "b": d2, "c": d2}, specs3(),
                                 MESH, machine=machine)


def rules(findings, severity=None):
    return [f.rule for f in findings
            if severity is None or f.severity == severity]


def test_solved_plan_lints_clean():
    plan = plan_lib.plan_line(pm.TPU_V5E, specs3(), MESH)
    findings = analysis.lint_plan(plan, specs=specs3(), mesh_shape=MESH)
    assert not rules(findings, "error"), [f.to_json() for f in findings]


def test_resharding_plan_lints_clean():
    findings = analysis.lint_plan(resharding_plan(), specs=specs3(),
                                  mesh_shape=MESH)
    assert not rules(findings, "error"), [f.to_json() for f in findings]


def test_dropped_reshard_fires():
    plan = resharding_plan()
    assert plan.layers["b"].reshard_in
    broken = dataclasses.replace(plan, layers={
        **plan.layers,
        "b": dataclasses.replace(plan.layers["b"], reshard_in=False)})
    found = analysis.lint_plan(broken, specs=specs3(), mesh_shape=MESH)
    assert "reshard-missing" in rules(found, "error"), \
        [f.to_json() for f in found]


def test_unpriced_reshard_fires():
    plan = resharding_plan()
    shuf = dict(plan.predicted["shuffle_per_layer"])
    shuf["b"] = 0.0
    broken = dataclasses.replace(
        plan, predicted={**plan.predicted, "shuffle_per_layer": shuf})
    found = analysis.lint_plan(broken, specs=specs3(), mesh_shape=MESH)
    assert "reshard-unpriced" in rules(found, "error")


def test_phantom_shuffle_fires():
    plan = resharding_plan()
    shuf = dict(plan.predicted["shuffle_per_layer"])
    shuf["c"] = 1e-3      # priced a shuffle into a layer with no reshard
    broken = dataclasses.replace(
        plan, predicted={**plan.predicted, "shuffle_per_layer": shuf})
    found = analysis.lint_plan(broken, specs=specs3(), mesh_shape=MESH)
    assert "phantom-shuffle" in rules(found, "error")


def test_memory_overrun_fires_naming_breakdown():
    plan = resharding_plan()
    mem = dict(plan.predicted["memory"])
    mem["limit_bytes"] = mem["peak_bytes"] / 2
    broken = dataclasses.replace(
        plan, predicted={**plan.predicted, "memory": mem})
    found = analysis.lint_plan(broken, specs=specs3(), mesh_shape=MESH)
    hits = [f for f in found
            if f.severity == "error" and f.rule == "memory-fit"]
    # the finding must carry the LayerMemory.breakdown() terms, not just
    # a bare overrun number
    assert hits and any("weights=" in f.message and "act_in=" in f.message
                        for f in hits), [f.to_json() for f in found]


def test_non_load_bearing_demotion_fires():
    plan = resharding_plan()
    lp = plan.layers["a"]
    # claim layer 'a' was demoted from... the dist it actually runs:
    # a recorded demotion that changed nothing is by definition not
    # load-bearing
    broken = dataclasses.replace(plan, layers={
        **plan.layers, "a": dataclasses.replace(lp, solved=lp.dist)})
    found = analysis.lint_plan(broken, specs=specs3(), mesh_shape=MESH)
    assert "demotion-not-load-bearing" in rules(found, "error")


def test_divisibility_violation_fires():
    # hand-build a plan whose dist cannot divide the layer: C=12 over a
    # 8-way channel group does not exist among executable candidates, so
    # force the dist in directly
    spec = pm.ConvLayer("a", n=4, c=8, h=16, w=16, f=8)
    plan = plan_lib.compile_plan(
        {"a": plan_lib._sharding_to_dist(
            ConvSharding(batch_axes=("data",), h_axis="model"))},
        [spec], MESH)
    found = analysis.lint_plan(
        plan, specs=[pm.ConvLayer("a", n=3, c=8, h=16, w=16, f=8)],
        mesh_shape=MESH)
    assert "divisibility" in rules(found, "error")


def test_finding_json_and_table_roundtrip():
    f = analysis.Finding("warning", "payload-mismatch", layer="conv1_1",
                         message="priced 10 B but moves 20 B",
                         fix="re-derive")
    j = f.to_json()
    assert json.loads(json.dumps(j)) == j
    assert j["severity"] == "warning" and j["layer"] == "conv1_1"
    table = analysis.format_findings([f])
    assert "payload-mismatch" in table and "conv1_1" in table
    assert analysis.format_findings([]).strip() == "no findings"
    assert analysis.error_count([f]) == 0
    assert analysis.error_count(
        [f, analysis.Finding("error", "x", message="m")]) == 1


def test_workload_registry_covers_bench():
    # the registry the static lane audits is the registry the bench times
    assert set(analysis.WORKLOADS) == {
        "mesh128", "overlap", "mesh16cf", "mesh2k_proxy", "mesh16_proxy",
        "mesh2k_unreachable"}


@pytest.mark.slow
def test_dist_audit():
    """Property + negative cases on 8 host devices (subprocess)."""
    run_dist_group("audit")
