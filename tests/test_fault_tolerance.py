"""Elastic fault-tolerance units (PR 8): plan-recording checkpoints and
their malformed-entry hygiene, the repro/plan@1 spec round trip, chaos
hooks, straggler detection, resilient-loop rollback determinism and the
DeviceLoss -> remesh handoff, step-addressable prefetch — plus the
4-device chaos acceptance (dist_checks group 'elastic')."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_dist_group
from repro.checkpoint.checkpoint import (SCHEMA, CheckpointError,
                                         CheckpointManager)
from repro.data.pipeline import Prefetcher
from repro.launch.mesh import elastic_factorization
from repro.runtime import chaos
from repro.runtime.fault_tolerance import (DeviceLoss, ResilientLoop,
                                           StragglerMonitor)
from repro.train.metrics import MetricsLogger


# ---------------------------------------------------------- checkpoints --
def test_checkpoint_ignores_malformed_entries_and_sweeps_tmp():
    d = tempfile.mkdtemp()
    try:
        # debris a crash / stray tooling leaves behind
        os.makedirs(os.path.join(d, "step-garbage"))
        os.makedirs(os.path.join(d, "step-"))
        os.makedirs(os.path.join(d, "tmp-7"))
        with open(os.path.join(d, "step-123"), "w") as f:
            f.write("a plain file, not a checkpoint dir")
        ck = CheckpointManager(d, keep=2, async_save=False)
        assert not [x for x in os.listdir(d) if x.startswith("tmp-")]
        assert ck.latest_step() is None          # nothing valid committed
        ck.save(5, {"w": jnp.arange(3.0)})
        ck.save(9, {"w": jnp.arange(3.0)})
        assert ck.latest_step() == 9
        got, manifest = ck.restore({"w": jnp.zeros(3)})
        assert manifest["schema"] == SCHEMA
        np.testing.assert_allclose(np.asarray(got["w"]), [0, 1, 2])
        # gc kept the garbage names out of the rotation accounting
        ck.save(11, {"w": jnp.arange(3.0)})
        steps = sorted(x for x in os.listdir(d)
                       if x.startswith("step-")
                       and os.path.isdir(os.path.join(d, x)))
        assert "step-garbage" in steps and "step-" in steps
    finally:
        shutil.rmtree(d)


def test_checkpoint_manifest_records_plan():
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_save=False)
        spec = {"schema": "repro/plan@1", "mesh": {"data": 2, "model": 2},
                "mem_limit": 1e6, "layers": {}}
        ck.save(3, {"w": jnp.zeros(2)}, extra={"step": 3}, plan=spec)
        m = ck.read_manifest()
        assert m["plan"]["mesh"] == {"data": 2, "model": 2}
        assert m["extra"]["step"] == 3
        # the restore-error hint names the recorded mesh
        with pytest.raises(CheckpointError, match="data"):
            ck.restore({"w": jnp.zeros(2), "x": jnp.zeros(1)})
    finally:
        shutil.rmtree(d)


def test_checkpoint_torn_manifest_raises():
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_save=False)
        os.makedirs(os.path.join(d, "step-4"))
        with open(os.path.join(d, "step-4", "manifest.json"), "w") as f:
            f.write("{not json")
        with pytest.raises(CheckpointError, match="torn"):
            ck.read_manifest(4)
    finally:
        shutil.rmtree(d)


# ------------------------------------------------------ plan spec record --
def test_plan_spec_roundtrip():
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.models.cnn import meshnet
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 4)
    mesh = {"data": 2, "model": 2}
    plan = plan_lib.plan_line(TPU_V5E, specs, mesh)
    spec = plan.to_spec(mesh, mem_limit=2.5e6, config_hash="abc",
                        calibration_fingerprint="deadbeef")
    blob = json.loads(json.dumps(spec))          # JSON-serializable
    assert blob["schema"] == plan_lib.PLAN_SCHEMA
    assert blob["mesh"] == mesh and blob["mem_limit"] == 2.5e6
    assert blob["config_hash"] == "abc"
    assert set(blob["layers"]) == set(plan.layers)
    dists = plan_lib.dists_from_spec(blob)
    re_plan = plan_lib.plan_from_spec(blob, specs, mesh, machine=TPU_V5E)
    for name, lp in plan.layers.items():
        assert dists[name].dims == re_plan.layers[name].dist.dims, name
    with pytest.raises(plan_lib.PlanError, match="schema"):
        plan_lib.dists_from_spec({"schema": "repro/plan@99", "layers": {}})
    with pytest.raises(plan_lib.PlanError, match="no entry"):
        plan_lib.plan_from_spec(
            {"schema": plan_lib.PLAN_SCHEMA,
             "layers": {"conv1_1": blob["layers"]["conv1_1"]}},
            specs, mesh, machine=TPU_V5E)


def test_elastic_factorization():
    assert elastic_factorization(4, batch=8) == (2, 2)
    assert elastic_factorization(3, batch=4) == (1, 3)   # nothing divides
    assert elastic_factorization(6, batch=6) == (2, 3)
    assert elastic_factorization(1) == (1, 1)
    assert elastic_factorization(8) == (2, 4)            # sqrt-balanced
    for n in (2, 3, 4, 5, 6, 7, 8):
        d, m = elastic_factorization(n, batch=4)
        assert d * m == n and 4 % d == 0


# -------------------------------------------------------------- straggler --
def test_straggler_warmup_suppresses_flags():
    mon = StragglerMonitor(k=5.0, warmup=3)
    assert not mon.record(0, 99.0)       # warmup: even huge steps pass
    assert not mon.record(1, 0.1)
    assert not mon.record(2, 0.1)


def test_straggler_mad_flags_and_action():
    hits = []
    mon = StragglerMonitor(k=5.0, warmup=3,
                           action=lambda s, dt: hits.append((s, dt)))
    for i in range(8):
        assert not mon.record(i, 0.1 + 0.001 * (i % 2))
    assert mon.record(8, 2.0)
    assert hits == [(8, 2.0)]
    assert mon.stats["flagged"] == 1
    assert mon.stats["p95"] >= mon.stats["median"]
    # mild jitter under 1.5x median is never a straggler
    assert not mon.record(9, 0.14)


# --------------------------------------------------------- resilient loop --
def _np_loop(ckdir, **kw):
    """A ResilientLoop over plain-numpy state with a real manager."""
    ck = CheckpointManager(ckdir, keep=3, async_save=False)

    def make_step():
        def run(state, step):
            return {"x": state["x"] * 0.9 + step}, {"loss": state["x"]}
        return run
    return ck, ResilientLoop(ckpt=ck, make_step=make_step, ckpt_every=5,
                             max_failures=2, **kw)


def test_rollback_determinism():
    """A faulted run lands on exactly the fault-free final state: rollback
    replays the identical step sequence from the last checkpoint."""
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        _, clean = _np_loop(d1)
        ref, step, _ = clean.run({"x": np.float32(1.0)}, 0, 12)
        ck, loop = _np_loop(d2)
        state, step, _ = loop.run({"x": np.float32(1.0)}, 0, 12,
                                  inject_failure=chaos.raise_at_step(7))
        assert step == 12
        np.testing.assert_array_equal(np.asarray(state["x"]),
                                      np.asarray(ref["x"]))
    finally:
        shutil.rmtree(d1)
        shutil.rmtree(d2)


def test_deviceloss_without_remesh_is_fatal():
    d = tempfile.mkdtemp()
    try:
        _, loop = _np_loop(d)
        with pytest.raises(DeviceLoss):
            loop.run({"x": np.float32(1.0)}, 0, 12,
                     inject_failure=chaos.drop_device_at_step(
                         3, devices=["d0", "d1", "d2", "d3"]))
    finally:
        shutil.rmtree(d)


def test_deviceloss_hands_survivors_to_remesh():
    d = tempfile.mkdtemp()
    seen = []
    try:
        ck, loop = _np_loop(d)

        def remesh(survivors):
            seen.append(list(survivors))

            def make_step():
                def run(state, step):
                    return {"x": state["x"] * 0.9 + step}, {}
                return run
            return make_step, {"x": np.float32(0.0)}     # template
        loop.remesh = remesh
        mpath = os.path.join(d, "m.jsonl")
        loop.metrics = MetricsLogger(mpath, echo=False)
        state, step, _ = loop.run({"x": np.float32(1.0)}, 0, 12,
                                  inject_failure=chaos.drop_device_at_step(
                                      7, n_drop=2,
                                      devices=["d0", "d1", "d2", "d3"]))
        loop.metrics.close()
        assert step == 12
        assert seen == [["d0", "d1"]]
        kinds = [json.loads(ln)["kind"] for ln in open(mpath)]
        assert "fault" in kinds and "remesh" in kinds \
            and "rollback" in kinds
    finally:
        shutil.rmtree(d)


def test_persistent_failure_gives_up():
    d = tempfile.mkdtemp()
    try:
        _, loop = _np_loop(d)
        with pytest.raises(RuntimeError, match="always"):
            loop.run({"x": np.float32(1.0)}, 0, 12,
                     inject_failure=lambda s: (_ for _ in ()).throw(
                         RuntimeError("always broken")))
    finally:
        shutil.rmtree(d)


# ------------------------------------------------------------------ chaos --
def test_chaos_parse_and_fire_once():
    h = chaos.parse("raise@2")
    h(0); h(1)
    with pytest.raises(RuntimeError, match="step 2"):
        h(2)
    h(2)                                     # disarmed after firing
    with pytest.raises(ValueError, match="kind@step"):
        chaos.parse("raise")
    with pytest.raises(ValueError, match="unknown"):
        chaos.parse("explode@3")
    with pytest.raises(ValueError, match="checkpoint dir"):
        chaos.parse("corrupt@3")
    k = chaos.parse("kill@1x2", devices=["a", "b", "c"])
    with pytest.raises(DeviceLoss) as ei:
        k(1)
    assert ei.value.survivors == ["a"]


def test_chaos_corrupt_plants_debris():
    d = tempfile.mkdtemp()
    try:
        h = chaos.parse("corrupt@0,raise@5", ckpt_dir=d)
        h(0)                                 # plants, does not raise
        assert os.path.isdir(os.path.join(d, "tmp-0"))
        assert os.path.isdir(os.path.join(d, "step-garbage"))
        ck = CheckpointManager(d, async_save=False)   # sweeps + ignores
        assert ck.latest_step() is None
        assert not os.path.exists(os.path.join(d, "tmp-0"))
        with pytest.raises(RuntimeError):
            h(5)
    finally:
        shutil.rmtree(d)


# ------------------------------------------------------------- prefetcher --
def test_prefetcher_step_addressable():
    pf = Prefetcher(lambda s: {"step": np.array([s])}, start_step=0)
    try:
        assert pf.get(0)["step"][0] == 0
        assert pf.get(3)["step"][0] == 3     # skips stale 1, 2 forward
        assert pf.get(1)["step"][0] == 1     # rollback: seek backward
        assert pf.get(2)["step"][0] == 2
    finally:
        pf.close()


# --------------------------------------------------- 4-device acceptance --
def test_elastic_distributed():
    """The chaos-lane acceptance: a 4-device run faulted mid-run recovers
    onto the 3 survivors via the recorded plan spec + re-solve and its
    post-restore loss trajectory matches the uninterrupted oracle
    (dist_checks group 'elastic', default mode kill-device; the CI chaos
    job drives all three fault modes)."""
    run_dist_group("elastic")
