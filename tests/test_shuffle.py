"""§III-C shuffle pricing: shuffle_time properties, the calibrated
`shuffle:` table path, and the regression pin that a calibration covers
every transition a compiled plan prices.

Calibration runs here use the fake-timer + fake-mesh pattern from
test_calibrate.py: the composition microbenchmarks are monkeypatched so
no kernel executes, but the *key plumbing* (which (p, bytes) shuffle
entries land in the table, and whether shuffle_time finds them) is
exercised for real.
"""
import dataclasses
import types

import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core import perfmodel as pm
from repro.core.distribution import Dist, hybrid, sample
from repro.core.perfmodel import (SHUFFLE_KIND, ConvLayer, EmpiricalTable,
                                  TPU_V5E, shuffle_block_bytes,
                                  shuffle_time)
from repro.models.cnn import meshnet

MS22 = {"data": 2, "model": 2}
LAYER = ConvLayer("c", n=4, c=16, h=32, w=32, f=16, k=3, s=1)
D_H = Dist("h", {"H": ("model",), "N": ("data",)})
D_W = Dist("w", {"W": ("model",), "N": ("data",)})


# ----------------------------------------------------------- properties --
def test_self_shuffle_is_free():
    assert shuffle_time(TPU_V5E, LAYER, D_H, D_H, MS22) == 0.0
    assert shuffle_time(TPU_V5E, LAYER, sample(("data", "model")),
                        sample(("data", "model")), MS22) == 0.0


def test_shuffle_is_symmetric():
    """§III-C: the all-to-all moves the same activation volume whichever
    direction the dist change goes — the priced cost must agree."""
    ab = shuffle_time(TPU_V5E, LAYER, D_H, D_W, MS22)
    ba = shuffle_time(TPU_V5E, LAYER, D_W, D_H, MS22)
    assert ab == ba > 0.0


def test_shuffle_factor_scales_analytic_fallback():
    m2 = dataclasses.replace(TPU_V5E, shuffle_factor=2.0)
    assert shuffle_time(m2, LAYER, D_H, D_W, MS22) == pytest.approx(
        2.0 * shuffle_time(TPU_V5E, LAYER, D_H, D_W, MS22))


def test_planted_table_entry_overrides_analytic():
    """A measured `shuffle:` key at the exact (p, bytes) the transition
    prices must be charged (2x: there and back), bypassing the analytic
    model and its factor entirely."""
    p = 4
    nb = shuffle_block_bytes(LAYER, p, TPU_V5E.wordsize)
    t = EmpiricalTable({(SHUFFLE_KIND, p, nb): 1.25e-4})
    m2 = dataclasses.replace(TPU_V5E, shuffle_factor=3.0)   # must be inert
    assert shuffle_time(m2, LAYER, D_H, D_W, MS22, table=t) == \
        pytest.approx(2 * 1.25e-4)


def test_lookup_shuffle_interpolates_and_bounds():
    t = EmpiricalTable({(SHUFFLE_KIND, 4, 1000): 1e-4,
                        (SHUFFLE_KIND, 4, 3000): 3e-4})
    assert t.lookup_shuffle(4, 1000) == pytest.approx(1e-4)
    assert t.lookup_shuffle(4, 2000) == pytest.approx(2e-4)
    # clamped to the endpoint inside the trusted (2x) band...
    assert t.lookup_shuffle(4, 4000) == pytest.approx(3e-4)
    # ...and silent (analytic fallback) far outside it
    assert t.lookup_shuffle(4, 100) is None
    assert t.lookup_shuffle(4, 10 ** 9) is None
    assert t.lookup_shuffle(8, 2000) is None       # other group size


# ------------------------------------- calibrated keys cover plan needs --
CFG = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                            convs_per_block=1, widths=(8, 16))
SPECS = meshnet.layer_specs(CFG, 4)


def fake_timer(fn, *args):
    return 2e-6 + 1e-9 * sum(int(np.prod(a.shape)) for a in args)


@pytest.fixture
def fake_calibrated(monkeypatch):
    """A calibration against a fake 'live' 2x2 mesh: every microbenchmark
    that would touch a device is replaced with a deterministic stand-in,
    so the shuffle/composed key families are exercised without devices."""
    monkeypatch.setattr(cal, "_bench_p2p",
                        lambda mesh, ax, nb, timer: 1e-6 + 1e-10 * nb)
    monkeypatch.setattr(cal, "_bench_collective",
                        lambda mesh, ax, op, nb, timer: 2e-6 + 2e-10 * nb)
    monkeypatch.setattr(cal, "_bench_overlap",
                        lambda mesh, ax, timer: {
                            "axis": ax, "p": 2, "t_overlap": 1e-4,
                            "t_serial": 1.5e-4, "t_compute": 1e-4,
                            "eta": 0.5})
    monkeypatch.setattr(cal, "_bench_shuffle",
                        lambda mesh, axes, nb, timer: 3e-6 + 1.5e-10 * nb)
    monkeypatch.setattr(
        cal, "_bench_product_halo",
        lambda mesh, axes, timer, **kw: {
            "axes": list(axes), "p": 4, "t_fused": 4e-4, "t_compute": 1e-4,
            "geom": {"o": 1, "n": 2, "c": 8, "h_l": 16, "w_l": 32,
                     "hops": 2}})
    monkeypatch.setattr(
        cal, "_bench_composed_cf",
        lambda mesh, cf_axis, sp_axis, timer, **kw: {
            "cf_axis": cf_axis, "sp_axis": sp_axis, "p_cf": 2, "p_sp": 2,
            "t_fused": 5e-4, "t_compute": 2e-4,
            "geom": {"o": 1, "n": 2, "c_l": 8, "f": 16, "h_l": 16,
                     "w_l": 32}})
    fake_mesh = types.SimpleNamespace(shape=MS22, devices=[])
    return cal.calibrate(SPECS, fake_mesh, timer=fake_timer)


def test_calibration_covers_every_priced_transition(fake_calibrated):
    """The regression pin: after calibration, every §III-C transition a
    compiled plan over these specs can price resolves to a measured
    `shuffle:` key (exact or interpolated) — never the analytic fallback.
    This is what 'the model/measured gap closes at the transitions the
    plan actually takes' rests on."""
    c = fake_calibrated
    assert any(k[0] == SHUFFLE_KIND for k in c.table.entries)
    p_total = 4
    for layer in SPECS:
        nb = shuffle_block_bytes(layer, p_total, c.machine.wordsize)
        assert c.table.lookup_shuffle(p_total, nb) is not None, layer.name
    # and the factors were fitted away from silence (meta records them)
    assert "shuffle_fit" in c.meta and "composed_fit" in c.meta
    assert c.machine.shuffle_factor > 0
    assert c.machine.composed_cf_factor > 0
    assert c.machine.composed_halo_factor > 0


def test_fake_composed_fit_is_deterministic(fake_calibrated):
    assert fake_calibrated.meta["composed_fit"]["cf_factor"] == \
        fake_calibrated.machine.composed_cf_factor
    assert fake_calibrated.meta["composed_fit"]["halo_factor"] == \
        fake_calibrated.machine.composed_halo_factor
    assert fake_calibrated.meta["shuffle_fit"]["factor"] == \
        fake_calibrated.machine.shuffle_factor


def test_refit_from_attribution_moves_factors(fake_calibrated, tmp_path):
    """A drift report (shuffle 2x under, comm 3x under) must push the
    factors up — and a second identical refit keeps compounding but stays
    inside the absolute clamp."""
    c = fake_calibrated
    f0 = (c.machine.shuffle_factor, c.machine.composed_cf_factor)
    rep = {"worst_term": "fp_comm",
           "terms": {"shuffle": {"drift": 2.0, "predicted_s": 1e-4},
                     "fp_comm": {"drift": 3.0, "predicted_s": 2e-4},
                     "bp_comm": {"drift": 3.0, "predicted_s": 2e-4}}}
    path = str(tmp_path / "cal.json")
    changed = cal.refit_from_attribution(c, rep, path=path)
    assert changed["shuffle_factor"] > f0[0]
    assert changed["composed_cf_factor"] > f0[1]
    assert c.meta["attribution_refits"][-1]["applied"] == changed
    # round-trips: the refit factors survive save/load
    c2 = cal.Calibration.load(path)
    assert c2.machine.shuffle_factor == c.machine.shuffle_factor
    for _ in range(10):
        cal.refit_from_attribution(c, rep)
    assert c.machine.shuffle_factor <= 10.0
    assert c.machine.composed_cf_factor <= 10.0


# --------------------------------------------------- mem capacity source --
def test_mem_capacity_env_override(monkeypatch):
    cal.detect_mem_capacity.cache_clear()
    monkeypatch.setenv("REPRO_MEM_CAPACITY", "123456789")
    try:
        assert cal.detect_mem_capacity() == 123456789.0
        assert cal.mem_capacity_source() == "env:REPRO_MEM_CAPACITY"
    finally:
        cal.detect_mem_capacity.cache_clear()


def test_mem_capacity_ignores_garbage_env(monkeypatch, capsys):
    cal.detect_mem_capacity.cache_clear()
    monkeypatch.setenv("REPRO_MEM_CAPACITY", "not-a-number")
    try:
        v = cal.detect_mem_capacity()
        assert v > 0
        assert cal.mem_capacity_source() != "env:REPRO_MEM_CAPACITY"
    finally:
        cal.detect_mem_capacity.cache_clear()
