"""Memory model tests (the §VI Table-2 companion of the §V perf model):
per-layer/network footprints (core.perfmodel.layer_memory/network_memory),
the capacity-constrained solve (core.strategy), plan-compile validation
(core.plan mem_limit) and the model-vs-XLA cross-check (core.calibrate).

The 4-device acceptance path (uniform sample-parallel infeasible under a
synthetic limit, solved plan fits + matches the oracle) lives in
tests/dist_checks.py group 'memfit'.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import perfmodel as pm
from repro.core.distribution import Dist
from repro.core.perfmodel import (ConvLayer, LayerMemory, layer_memory,
                                  network_memory)
from repro.core.plan import (PlanError, compile_plan, executable_candidates,
                             plan_line)
from repro.core.strategy import CapacityError, prune_by_memory, solve_line
from repro.models.cnn import meshnet

M = dataclasses.replace(pm.LASSEN, wordsize=4)   # fp32 words, 16 GB device
MS22 = {"data": 2, "model": 2}
MS222 = {"pod": 2, "data": 2, "model": 2}
REP = Dist("replicated", {})


# ------------------------------------------------------- word-count pins --
def test_act_words_uses_output_extents():
    """Output activations live at h_out/w_out — strided convs and pools
    shrink (the §VI accounting regression this PR pins down)."""
    strided = ConvLayer("s", n=4, c=8, h=32, w=32, f=16, k=3, s=2)
    pool = ConvLayer("p", n=4, c=16, h=32, w=32, f=16, k=3, s=2,
                     kind="pool")
    assert strided.act_words() == 4 * 16 * 16 * 16       # not 32x32
    assert pool.act_words() == 4 * 16 * 16 * 16


def test_layer_memory_word_counts_strided_conv():
    """Exact fwd+bwd byte counts for a strided conv, replicated and under
    a 2-way H split (pins the h_out/w_out extents in act_out and in the
    backward dL/dy halo buffer)."""
    layer = ConvLayer("s", n=4, c=8, h=32, w=32, f=16, k=3, s=2)
    lm = layer_memory(M, layer, REP, {})
    assert lm.weights == lm.grads == lm.opt == 3 * 3 * 8 * 16 * 4
    assert lm.act_in == 4 * 8 * 32 * 32 * 4
    assert lm.act_out == 4 * 16 * 16 * 16 * 4            # output extents
    assert lm.stash == 2 * lm.act_in + lm.act_out
    assert lm.halo == lm.cf == 0
    assert lm.total == lm.weights * 3 + 2 * lm.act_in + lm.act_out

    ms = {"m": 2}
    lm_h = layer_memory(M, layer, Dist("h", {"H": ("m",)}), ms)
    assert lm_h.act_in == lm.act_in / 2
    assert lm_h.act_out == lm.act_out / 2
    # fwd halo on x: 2*o*n*c*w_local; bwd halo on dL/dy: 2*o*n*f*w_out_local
    # — equal here (c*w == f*w_out at s=2, f=2c), which pins that the bwd
    # buffer uses OUTPUT extents: with input extents it would be 2x larger
    # and the max() would change the answer.
    assert lm_h.halo == 2 * 1 * 4 * 8 * 32 * 4
    assert lm_h.halo == 2 * 1 * 4 * 16 * 16 * 4


def test_layer_memory_word_counts_pool():
    """Pool layers hold no weights/grads/optimizer words; activations pin
    the same output-extents rule."""
    layer = ConvLayer("p", n=4, c=16, h=32, w=32, f=16, k=3, s=2,
                      kind="pool")
    lm = layer_memory(M, layer, REP, {})
    assert lm.weights == lm.grads == lm.opt == 0
    assert lm.act_in == 4 * 16 * 32 * 32 * 4
    assert lm.act_out == 4 * 16 * 16 * 16 * 4
    assert lm.total == 2 * lm.act_in + lm.act_out
    # max-pool backward needs its input: the stash is real for pools too
    assert lm.stash == 2 * lm.act_in + lm.act_out


def test_layer_memory_cf_shards_weights():
    """Under a CF dist both §III-D modes hold weight_words/p_cf resident,
    plus the staging buffer of the cheaper collective."""
    layer = ConvLayer("cf", n=4, c=16, h=8, w=8, f=32, k=3, s=1)
    cf = Dist("cf", {"N": ("data",), "C": ("model",), "F": ("model",)})
    lm = layer_memory(M, layer, cf, MS22)
    rep = layer_memory(M, layer, Dist("n", {"N": ("data",)}), MS22)
    assert lm.weights == rep.weights / 2
    assert lm.grads == rep.grads / 2 and lm.opt == rep.opt / 2
    words = pm.cf_collective_words(layer, cf, MS22)
    assert lm.cf == min(words["ag_x"], words["rs_y"]) * 4
    assert rep.cf == 0


# ------------------------------------------------------ property checks --
LAYERS = [
    ConvLayer("big", n=8, c=16, h=64, w=64, f=32, k=3, s=1),
    ConvLayer("strided", n=4, c=8, h=32, w=32, f=16, k=3, s=2),
    ConvLayer("late", n=2, c=32, h=8, w=8, f=64, k=3, s=1),
    ConvLayer("pool", n=8, c=16, h=32, w=32, f=16, k=3, s=2, kind="pool"),
    ConvLayer("pred", n=2, c=64, h=8, w=8, f=1, k=1, s=1),
]
MESHES = [MS22, MS222, {"data": 4, "model": 2}, {"data": 2}]


def test_layer_memory_finite_positive_over_candidate_families():
    """Every dist executable_candidates emits yields a finite, positive
    footprint with non-negative components, on every mesh."""
    for ms in MESHES:
        for layer in LAYERS:
            for d in executable_candidates(layer, ms):
                lm = layer_memory(M, layer, d, ms)
                assert math.isfinite(lm.total) and lm.total > 0, (layer, d)
                for f in dataclasses.fields(LayerMemory):
                    assert getattr(lm, f.name) >= 0, (layer, d, f.name)


def test_layer_memory_monotone_as_spatial_grid_grows():
    """Growing the spatial shard grid never increases the footprint: the
    activation terms shrink with the grid while halo buffers stay fixed —
    the §VI forcing function that makes spatial decomposition the only way
    down once sample parallelism hits one sample per device."""
    layer = ConvLayer("c", n=2, c=8, h=64, w=64, f=8, k=3, s=1)
    # deeper single-axis splits
    prev = None
    for p in (2, 4, 8):
        t = layer_memory(M, layer, Dist("h", {"H": ("m",)}), {"m": p}).total
        if prev is not None:
            assert t <= prev, p
        prev = t
    # widening a split into a product axis (the 16x16-mesh move)
    ms = {"a": 2, "b": 2}
    t_one = layer_memory(M, layer, Dist("h", {"H": ("a",)}), ms).total
    t_prod = layer_memory(M, layer, Dist("hh", {"H": ("a", "b")}), ms).total
    t_hw = layer_memory(M, layer,
                        Dist("hw", {"H": ("a",), "W": ("b",)}), ms).total
    assert t_prod <= t_one and t_hw <= t_one
    # and the unsplit layer is the ceiling
    t_rep = layer_memory(M, layer, REP, ms).total
    assert t_one <= t_rep


def test_network_memory_accumulates_stashes():
    """The network peak is larger than any single layer's resident set:
    forward stashes of earlier layers stay live (what remat-free training
    actually holds)."""
    specs = meshnet.layer_specs(
        meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                              convs_per_block=1, widths=(8, 16)), 4)
    dists = [REP] * len(specs)
    net = network_memory(M, specs, dists, {})
    worst = max(lm.total for lm in net["per_layer"])
    assert net["peak_bytes"] > worst
    assert net["peak_layer"] == specs[-1].name     # stash-accumulated tail


def test_memory_model_agrees_with_xla_within_2x():
    """Predicted peak vs XLA's compiled memory_analysis on a small compiled
    plan (single device): within the 2x property tolerance — the §VI
    cross-check the dryrun pattern proves out (core.calibrate)."""
    from repro.core import calibrate as calib
    from repro.data.pipeline import synthetic_mesh_batch
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 4)
    # opt_words=0: the compiled step is loss+grads, no optimizer state
    plan = plan_line(M, specs, {"d": 1}, opt_words=0.0)
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(0, 4, 32, 4, out_hw=8).items()}
    step = jax.jit(jax.value_and_grad(
        lambda p, bb: meshnet.loss_fn(p, bb, cfg, plan, None)))
    res = calib.crosscheck_memory(plan, step, params, b)
    assert res["measured_bytes"] > 0, "backend exposes no memory_analysis"
    assert 0.5 <= res["ratio"] <= 2.0, res


# -------------------------------------------------- solver + plan layers --
def test_prune_by_memory_keeps_fitting_dists():
    layer = ConvLayer("c", n=4, c=8, h=32, w=32, f=8, k=3, s=1)
    cands = executable_candidates(layer, MS22)
    totals = [layer_memory(M, layer, d, MS22).total for d in cands]
    lim = sorted(totals)[len(totals) // 2]
    kept = prune_by_memory(M, layer, cands, MS22, lim)
    assert kept and all(
        layer_memory(M, layer, d, MS22).total <= lim for d in kept)
    # no limit: everything passes through
    assert prune_by_memory(M, layer, cands, MS22, None) == list(cands)


def test_capacity_error_names_layer_and_breakdown():
    """CapacityError follows the PlanError diagnostics discipline: layer
    name, smallest-achievable footprint, the dist achieving it, and the
    weights/acts/halo/grads breakdown."""
    layer = ConvLayer("res9", n=4, c=8, h=32, w=32, f=8, k=3, s=1)
    cands = executable_candidates(layer, MS22)
    with pytest.raises(CapacityError, match=r"'res9'.*smallest"):
        prune_by_memory(M, layer, cands, MS22, 64.0)
    try:
        prune_by_memory(M, layer, cands, MS22, 64.0)
    except CapacityError as e:
        msg = str(e)
        assert "act_in=" in msg and "weights=" in msg and "grads=" in msg
        best = min(cands, key=lambda d: layer_memory(M, layer, d,
                                                     MS22).total)
        assert repr(best.name) in msg


def test_solve_line_respects_memory_limit():
    """min-time SUBJECT TO the capacity constraint: with the limit, every
    solved dist fits; without, the solver may pick bigger-footprint ones."""
    specs = meshnet.layer_specs(
        meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                              convs_per_block=1, widths=(8, 16)), 2)
    cands = [executable_candidates(l, MS22) for l in specs]
    lim = max(min(layer_memory(M, l, d, MS22).total for d in cs)
              for l, cs in zip(specs, cands)) * 1.05
    res = solve_line(M, specs, cands, MS22, mem_limit=lim)
    for l, d in zip(specs, res.dists):
        assert layer_memory(M, l, d, MS22).total <= lim, (l.name, d)


def test_compile_plan_validates_fit_with_breakdown():
    specs = [ConvLayer("a", n=8, c=4, h=32, w=32, f=8, k=3, s=1)]
    dists = {"a": Dist("sample", {"N": ("data", "model")})}
    with pytest.raises(PlanError, match=r"(?s)does not fit.*act_in="):
        compile_plan(dists, specs, MS22, machine=M, mem_limit=1024.0)
    # mem_limit without a machine is a usage error, not a silent skip
    with pytest.raises(PlanError, match="machine"):
        compile_plan(dists, specs, MS22, mem_limit=1024.0)


def test_demotion_note_records_capacity_violation():
    """A geometry demotion falls back to a coarser split; when that blows
    the capacity limit the note (and the raised PlanError) say so."""
    # H=4 over 2-way model with k=3: spatial demotes to sample-parallel,
    # whose footprint exceeds the tiny limit
    specs = [ConvLayer("a", n=8, c=16, h=4, w=4, f=16, k=3, s=1)]
    dists = {"a": Dist("hybrid", {"N": ("data",), "H": ("model",)})}
    demoted = layer_memory(M, specs[0], Dist("n", {"N": ("data",)}),
                           MS22).total
    with pytest.raises(PlanError, match="demotion violates capacity"):
        compile_plan(dists, specs, MS22, machine=M,
                     mem_limit=demoted * 0.9)
    # with headroom the same plan compiles, note records the demotion only
    plan = compile_plan(dists, specs, MS22, machine=M,
                        mem_limit=demoted * 10)
    assert "demoted" in plan.layers["a"].note
    assert "violates capacity" not in plan.layers["a"].note


def test_plan_line_memory_aware_solve_changes_plan():
    """The analytic half of the dist_checks 'memfit' acceptance: batch <
    devices makes sample parallelism memory-bound; under the limit the
    solve goes spatial and the recorded report carries limit + peak."""
    specs = meshnet.layer_specs(
        meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                              convs_per_block=1, widths=(8, 16),
                              bn_scope="global"), 2)
    sample = [Dist("s", {"N": ("data",)})] * len(specs)
    sample_peak = network_memory(pm.TPU_V5E, specs, sample,
                                 MS22)["peak_bytes"]
    limit = 0.75 * sample_peak
    plan = plan_line(pm.TPU_V5E, specs, MS22, mem_limit=limit)
    mem = plan.predicted["memory"]
    assert mem["peak_bytes"] <= limit < sample_peak
    assert mem["limit_bytes"] == limit
    assert any(lp.sharding.is_spatial for lp in plan.layers.values())
    assert "limit" in plan.describe()
    # per-layer breakdowns ride along, keyed by layer name
    assert set(mem["per_layer"]) == {l.name for l in specs}


def test_plan_line_infeasible_limit_raises():
    specs = meshnet.layer_specs(
        meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                              convs_per_block=1, widths=(8, 16)), 2)
    with pytest.raises((CapacityError, PlanError)):
        plan_line(pm.TPU_V5E, specs, MS22, mem_limit=256.0)


# --------------------------------------------------- capacity detection --
def test_detect_mem_capacity_host_fallback():
    """On this CPU container memory_stats() is None, so the /proc/meminfo
    share (or the default) answers — finite, positive, and memoized so
    calibrations stay deterministic within a process."""
    from repro.core.calibrate import detect_mem_capacity
    cap = detect_mem_capacity()
    assert math.isfinite(cap) and cap > 0
    assert detect_mem_capacity() == cap


def test_calibration_roundtrips_mem_capacity():
    from repro.core.calibrate import Calibration
    from repro.core.perfmodel import EmpiricalTable
    mach = dataclasses.replace(M, mem_capacity=123456.0)
    cal = Calibration(machine=mach, table=EmpiricalTable({}), meta={})
    back = Calibration.from_json(cal.to_json())
    assert back.machine.mem_capacity == 123456.0
