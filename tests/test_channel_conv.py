"""Channel/filter-parallel conv runtime (core.channel_conv) tests.

Single-device half here (dense fallbacks are the 1x1-mesh oracle path and
must be bitwise-identical; the Pallas implicit-GEMM backend runs in
interpret mode on CPU).  The multi-device parity half — both CF modes vs
the dense oracle, fwd + grads, BN/bias, and the solved-plan acceptance
check — lives in tests/dist_checks.py group 'cf' (subprocess, 8 host
devices), run by tests/test_plan.py::test_plan_cf_distributed, which is
intentionally NOT marked slow so the CI fast lane exercises the CF
parity group too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.channel_conv import (CFSharding, cf_batch_norm, cf_bias_add,
                                     cf_conv2d)
from repro.core.spatial_conv import ConvSharding, spatial_conv2d
from repro.core.spatial_norm import batch_norm
from repro.utils import same_pads


def _oracle(x, w, s=1):
    k_h, k_w = w.shape[0], w.shape[1]
    return lax.conv_general_dilated(
        x, w, (s, s), (same_pads(k_h, s), same_pads(k_w, s)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# -------------------------------------------------------------- descriptor --
def test_cfsharding_surface():
    sh = CFSharding(batch_axes=("data",), cf_axis="model")
    assert not sh.is_spatial
    assert sh.h_axis is None and sh.w_axis is None
    assert sh.fit(32, 32, 3, 1, None) == sh          # geometry fit: no-op
    assert tuple(sh.x_spec()) == (("data",), None, None, "model")
    assert sh.fits_channels(8, 16, {"model": 2})
    assert not sh.fits_channels(5, 16, {"model": 2})
    assert not sh.fits_channels(8, 7, {"model": 2})
    with pytest.raises(ValueError):
        CFSharding(cf_axis="model", mode="diagonal")


# ----------------------------------------------------- dense (1x1) fallback --
def test_cf_conv_dense_fallback_bitwise():
    """cf_axis on a size-1 (or absent) mesh takes the dense path and is
    bitwise-identical to both the oracle and the spatial dense path."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 6, 4)) * 0.1
    for mode in ("channel", "filter"):
        got = cf_conv2d(x, w, sharding=CFSharding(mode=mode))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(_oracle(x, w)))
    sp = spatial_conv2d(x, w, sharding=ConvSharding())
    np.testing.assert_array_equal(
        np.asarray(cf_conv2d(x, w, sharding=CFSharding())), np.asarray(sp))


def test_cf_conv_dense_strided():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 6, 4)) * 0.1
    got = cf_conv2d(x, w, strides=(2, 2), sharding=CFSharding())
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_oracle(x, w, 2)))


def test_cf_bn_dense_matches_spatial_norm_bitwise():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 6)) * 3 + 1
    g = jax.random.normal(jax.random.PRNGKey(1), (6,)) + 2
    b = jax.random.normal(jax.random.PRNGKey(2), (6,))
    ref = batch_norm(x, g, b, sharding=ConvSharding(), scope="local")
    for scope in ("local", "spatial", "global"):
        got = cf_batch_norm(x, g, b, sharding=CFSharding(), scope=scope)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    with pytest.raises(ValueError):
        cf_batch_norm(x, g, b, sharding=CFSharding(), scope="galactic")


def test_cf_bias_dense():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 6))
    b = jax.random.normal(jax.random.PRNGKey(1), (6,))
    got = cf_bias_add(x, b, sharding=CFSharding())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x + b))


# ------------------------------------------------ pallas interpret backend --
def test_cf_conv_pallas_interpret_parity():
    """backend='pallas' routes the CF local conv through the implicit-GEMM
    MXU kernel; interpret mode on CPU is numerics-identical to the TPU
    lowering, so parity here is parity there."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 8)) * 0.1
    got = cf_conv2d(x, w, sharding=CFSharding(), backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(_oracle(x, w)),
                               rtol=2e-6, atol=2e-6)


def test_cf_mixed_precision_casts_to_weight_dtype():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 4))
    w = (jax.random.normal(jax.random.PRNGKey(1), (1, 1, 4, 4)) * 0.1
         ).astype(jnp.bfloat16)
    y = cf_conv2d(x, w, sharding=CFSharding())
    assert y.dtype == jnp.bfloat16


def test_mixed_precision_rule_unified_across_conv_paths():
    """Both conv runtimes share cast_to_weight_dtype (compute in the
    *weight* dtype), so a mixed sample/spatial/CF plan cannot change
    dtype — or numerics — at a reshard boundary: the same layer computes
    the same values whichever decomposition executes it."""
    from repro.core.spatial_conv import cast_to_weight_dtype
    x32 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 4))
    w16 = (jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 4)) * 0.1
           ).astype(jnp.bfloat16)
    # f32 activations into bf16 weights: both paths compute in bf16
    y_sp = spatial_conv2d(x32, w16, sharding=ConvSharding())
    y_cf = cf_conv2d(x32, w16, sharding=CFSharding())
    assert y_sp.dtype == y_cf.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(y_sp), np.asarray(y_cf))
    # bf16 activations into f32 weights: both paths upcast to f32
    x16 = x32.astype(jnp.bfloat16)
    w32 = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 4)) * 0.1
    y_sp = spatial_conv2d(x16, w32, sharding=ConvSharding())
    y_cf = cf_conv2d(x16, w32, sharding=CFSharding())
    assert y_sp.dtype == y_cf.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(y_sp), np.asarray(y_cf))
    # the shared helper is the single source of the rule
    assert cast_to_weight_dtype(x32, w16).dtype == jnp.bfloat16
    assert cast_to_weight_dtype(x16, w32).dtype == jnp.float32
    assert cast_to_weight_dtype(x32, w32) is x32      # no-op when equal


def test_cfsharding_spatial_composition_surface():
    """CFSharding carries composed spatial axes: spec, fit and the
    same-axis guard."""
    sh = CFSharding(batch_axes=("pod",), cf_axis="model",
                    h_axis=("data", "x"))
    assert sh.is_spatial and sh.h_axes == ("data", "x")
    assert tuple(sh.x_spec()) == (("pod",), ("data", "x"), None, "model")
    # geometry fit drops an unfit product split (shard < kernel)
    fitted = sh.fit(4, 4, 3, 1, _FakeMesh({"data": 2, "x": 2,
                                           "model": 2, "pod": 2}))
    assert fitted.h_axis is None and fitted.cf_axis == "model"
    # cf axis colliding with a spatial axis is rejected at construction
    with pytest.raises(ValueError):
        CFSharding(cf_axis="model", h_axis=("model", "data"))


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
