"""End-to-end system behaviour: the paper's workflow on one device —
spatial-parallel model built, trained, checkpointed, restored, resumed;
strategy optimizer drives per-layer distributions end to end."""
import functools
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import perfmodel as pm, strategy as strat
from repro.core.spatial_conv import ConvSharding
from repro.data.pipeline import synthetic_mesh_batch
from repro.models.cnn import meshnet
from repro.optim.optimizer import sgd
from repro.train.train_loop import TrainStepConfig, make_train_step
from repro.utils import FP32


def test_end_to_end_train_checkpoint_resume():
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=2,
                                convs_per_block=1, widths=(4, 8))
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    loss = functools.partial(meshnet.loss_fn, cfg=cfg,
                             plan=ConvSharding())
    opt = sgd(0.05, momentum=0.9)
    ostate = opt.init(params)

    def batch(i):
        return {k: jnp.asarray(v) for k, v in
                synthetic_mesh_batch(i, 4, 32, 2, out_hw=8).items()}

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(loss)(p, b)
        p, s = opt.update(g, s, p)
        return p, s, l

    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_save=False)
        for i in range(6):
            params, ostate, l = step(params, ostate, batch(i))
        ck.save(6, (params, ostate))
        # "crash": clobber state, restore, continue deterministically
        params2 = meshnet.init(jax.random.PRNGKey(99), cfg)
        (params, ostate), m = ck.restore((params2, opt.init(params2)))
        assert m["step"] == 6
        p_a, s_a, l_a = step(params, ostate, batch(6))
        # re-restore and repeat: identical trajectory (determinism)
        (params, ostate), _ = ck.restore((params2, opt.init(params2)))
        p_b, s_b, l_b = step(params, ostate, batch(6))
        assert float(l_a) == float(l_b)
        for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_strategy_to_execution():
    """§V-C output drives per-layer distributions in the model, through the
    plan compiler (core.plan) and through the legacy per-layer list."""
    from repro.core import plan as plan_lib
    cfg = meshnet.MeshNetConfig("t", input_hw=64, in_channels=4,
                                convs_per_block=1, widths=(8, 16, 16))
    ms = {"data": 1, "model": 1}     # single device: all dists are trivial
    layers = meshnet.layer_specs(cfg, 4)
    p = meshnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 4))

    plan = plan_lib.plan_line(pm.LASSEN, layers, ms)
    y = meshnet.apply(p, x, cfg, plan)
    assert y.shape == (2, 8, 8, 1)
    assert np.isfinite(np.asarray(y)).all()
    assert plan.predicted is not None and plan.predicted["total"] > 0

    # legacy path: a hand-lowered per-layer ConvSharding list still works
    cands = [strat.candidate_dists(l, ms) for l in layers]
    res = strat.solve_line(pm.LASSEN, layers, cands, ms)
    shardings = [ConvSharding(
        batch_axes=d.axes("N"), h_axis=(d.axes("H") or (None,))[0])
        for d in res.dists]
    y2 = meshnet.apply(p, x, cfg, shardings)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y))


def test_train_step_builder_grad_accum_equivalence():
    """grad_accum=2 ~= grad_accum=1 on the same global batch (fp32).

    Not bit-equal: BatchNorm statistics are per-microbatch (the classic
    grad-accum caveat, same as the paper's out-of-core micro-batching
    reference [43]) — tolerance covers the small stats shift."""
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=2,
                                convs_per_block=1, widths=(4,))
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    loss = functools.partial(meshnet.loss_fn, cfg=cfg,
                             plan=ConvSharding())
    opt = sgd(0.1, momentum=0.0)

    class _M:
        axis_names = ()
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(0, 4, 32, 2, out_hw=16).items()}
    outs = []
    for ga in (1, 2):
        stepf = make_train_step(lambda p, bb: loss(p, bb), opt, _M(),
                                TrainStepConfig(grad_accum=ga,
                                                precision=FP32))
        p0 = jax.tree.map(jnp.copy, params)   # step donates its inputs
        p, o, ef, m = stepf(p0, opt.init(p0), None, dict(b))
        outs.append((p, float(m["loss"])))
    assert abs(outs[0][1] - outs[1][1]) < 0.05 * abs(outs[0][1])
    for a, c in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=3e-2, atol=1e-3)
