"""Unit/property tests for core primitives on a single device (the
multi-device halves live in tests/test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.models.lm import modules as M
from repro.models.lm.config import LMConfig
from repro.utils import cdiv, human_bytes, round_up, same_pads

CFG = LMConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
               n_kv_heads=2, head_dim=8, d_ff=64, vocab=97)


# ------------------------------------------------------------------ utils --
@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 9), s=st.integers(1, 4))
def test_same_pads_preserves_size(k, s):
    """'SAME': out = in/s for any in divisible by s."""
    lo, hi = same_pads(k, s)
    n = 8 * s * max(k, 1)
    out = (n + lo + hi - k) // s + 1
    assert out == n // s


@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 1000), b=st.integers(1, 64))
def test_cdiv_roundup(a, b):
    assert cdiv(a, b) * b >= a
    assert cdiv(a, b) * b - a < b
    assert round_up(a, b) % b == 0


def test_human_bytes():
    assert human_bytes(1536) == "1.50KiB"
    assert human_bytes(3 * 2 ** 30) == "3.00GiB"


# ------------------------------------------------------------------- rope --
def test_rope_preserves_norm_and_relative():
    """Rotations preserve vector norm; scores depend on relative offset."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = M.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> == <rope(q,i+d), rope(k,j+d)>
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def score(i, j):
        qi = M.rope(q, jnp.array([i]), 1e4)
        kj = M.rope(k, jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(score(3, 1) - score(7, 5)) < 1e-4


# ------------------------------------------------------------------ norms --
@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm", "nonparam_ln"])
def test_norms(kind):
    import dataclasses
    cfg = dataclasses.replace(CFG, norm=kind)
    w = M.norm_init(cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, cfg.d_model)) * 3
    y = M.norm_apply(cfg, w, x)
    assert y.shape == x.shape
    if kind == "rmsnorm":
        rms = np.sqrt(np.mean(np.square(np.asarray(y)), -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-2)
    else:
        np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0,
                                   atol=1e-4)


# -------------------------------------------------------------------- moe --
def test_moe_top1_routes_all_tokens():
    """With capacity_factor >= n_experts, nothing is dropped and the output
    equals a per-token expert MLP (top-1)."""
    import dataclasses
    cfg = dataclasses.replace(CFG, n_experts=4, top_k=1, capacity_factor=4.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    from repro.models.lm.modules import ShardCtx
    y = M.moe_apply(p, x, cfg, ShardCtx())
    # dense per-token reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    idx = jnp.argmax(logits, -1)
    h = jnp.einsum("td,tdf->tf", xt, p["wi"][idx])
    g = jnp.einsum("td,tdf->tf", xt, p["wg"][idx])
    ref = jnp.einsum("tf,tfd->td", jax.nn.silu(g) * h, p["wo"][idx])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops():
    """With tiny capacity, outputs are bounded (dropped tokens -> zero)."""
    import dataclasses
    cfg = dataclasses.replace(CFG, n_experts=2, top_k=1,
                              capacity_factor=0.25)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    from repro.models.lm.modules import ShardCtx
    y = M.moe_apply(p, x, cfg, ShardCtx())
    # at least some tokens must have been dropped to zero output
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-7).sum() >= 1


# --------------------------------------------------------------- ssd math --
def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == the literal h_t = a_t h_{t-1} + xdt_t B_t recurrence."""
    from repro.models.lm.modules import _ssd_chunked
    key = jax.random.PRNGKey(0)
    b, l, h, p, n = 1, 12, 2, 4, 3
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    la = -jax.random.uniform(ks[1], (b, l, h), minval=0.05, maxval=0.5)
    B = jax.random.normal(ks[2], (b, l, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, n)) * 0.5
    y, hfin = _ssd_chunked(xdt, la, B, C, chunk=4)
    a = jnp.exp(la)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        state = state * a[:, t, :, None, None] + \
            jnp.einsum("bhp,bn->bhpn", xdt[:, t], B[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C[:, t]))
    ref = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(hfin), np.asarray(state),
                               rtol=2e-4, atol=2e-5)
