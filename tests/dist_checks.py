"""Multi-device distributed checks, run in a subprocess so the main pytest
process keeps a single CPU device (the 512-device env is dry-run-only).

Usage:  python tests/dist_checks.py <group>
Groups: conv | attention | ssm | models | train | compress | plan | cf |
        spatial2d | multiaxis | memfit | overlap | trace | elastic | audit
Exits 0 on success; any assertion failure exits non-zero.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch.mesh import make_mesh  # noqa: E402
from repro.utils import same_pads  # noqa: E402


def oracle_conv(x, w, s):
    kh, kw = w.shape[0], w.shape[1]
    return lax.conv_general_dilated(
        x, w, (s, s), (same_pads(kh, s), same_pads(kw, s)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def check_conv():
    from repro.core.spatial_conv import spatial_conv2d, spatial_pool, \
        ConvSharding
    mesh = make_mesh(data=2, model=4)
    key = jax.random.PRNGKey(0)
    for (K, s, H, W, C, F) in [(3, 1, 16, 12, 5, 7), (7, 2, 32, 16, 3, 8),
                               (1, 1, 16, 8, 4, 4), (3, 2, 16, 16, 6, 6)]:
        x = jax.random.normal(key, (4, H, W, C), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (K, K, C, F)) * 0.1
        ref = oracle_conv(x, w, s)
        for overlap in (False, True):
            sh = ConvSharding(batch_axes=("data",), h_axis="model")
            with mesh:
                got = jax.jit(lambda x, w: spatial_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh,
                    overlap=overlap))(x, w)
                np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                           rtol=2e-5, atol=2e-5)
                gd = jax.jit(jax.grad(lambda x, w: jnp.sum(spatial_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh,
                    overlap=overlap) ** 2), argnums=(0, 1)))(x, w)
            gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, s) ** 2),
                          argnums=(0, 1))(x, w)
            for a, b in zip(gd, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-4, atol=3e-4)
    # pooling (max needs -inf edge halo) and 2-D H x W decomposition
    x = jax.random.normal(key, (4, 32, 16, 5), jnp.float32)
    for kind in ("max", "avg"):
        sh = ConvSharding(batch_axes=("data",), h_axis="model")
        with mesh:
            got = jax.jit(lambda x: spatial_pool(
                x, window=(3, 3), strides=(2, 2), sharding=sh, mesh=mesh,
                kind=kind))(x)
        ref = spatial_pool(x, window=(3, 3), strides=(2, 2),
                           sharding=ConvSharding(), kind=kind)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    sh2 = ConvSharding(batch_axes=(), h_axis="model", w_axis="data")
    x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.1
    with mesh:
        got = jax.jit(lambda x, w: spatial_conv2d(
            x, w, strides=(1, 1), sharding=sh2, mesh=mesh))(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle_conv(
        x, w, 1)), rtol=2e-5, atol=2e-5)
    # spatially-aggregated batch norm == global stats over the shards
    from repro.core.spatial_norm import batch_norm
    sh = ConvSharding(batch_axes=("data",), h_axis="model")
    x = jax.random.normal(key, (4, 16, 8, 6), jnp.float32) * 3 + 1
    g = jnp.ones((6,)); b = jnp.zeros((6,))
    with mesh:
        got = jax.jit(lambda x: batch_norm(
            x, g, b, sharding=sh, mesh=mesh, scope="global"))(x)
    ref = batch_norm(x, g, b, sharding=ConvSharding(), scope="local")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def check_attention():
    from repro.core.ring_attention import ring_attention
    from repro.core.decode_attention import decode_attention, cache_append
    mesh = make_mesh(data=2, model=4)
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 2, 32, 8, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    for causal, window, cap in [(True, None, None), (True, 7, None),
                                (False, None, None), (True, 12, 30.0)]:
        ref = ring_attention(q, k, v, mesh=None, seq_axis=None,
                             causal=causal, window=window, softcap=cap)
        with mesh:
            got = jax.jit(lambda q, k, v: ring_attention(
                q, k, v, mesh=mesh, seq_axis="model", causal=causal,
                window=window, softcap=cap))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    qd = jax.random.normal(ks[0], (B, 1, Hq, D))
    L = jnp.int32(23)
    for window in (None, 6):
        ref = decode_attention(qd, k, v, L, mesh=None, seq_axis=None,
                               window=window)
        with mesh:
            got = jax.jit(lambda q, k, v, L: decode_attention(
                q, k, v, L, mesh=mesh, seq_axis="model",
                window=window))(qd, k, v, L)
            # multi-axis sequence sharding (long_500k layout)
            got2 = jax.jit(lambda q, k, v, L: decode_attention(
                q, k, v, L, mesh=mesh, seq_axis=("data", "model"),
                batch_axes=(), window=window))(qd, k, v, L)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    kn = jax.random.normal(ks[1], (B, 1, Hkv, D))
    vn = jax.random.normal(ks[2], (B, 1, Hkv, D))
    kr, vr = cache_append(k, v, kn, vn, 23, mesh=None, seq_axis=None)
    with mesh:
        kg, vg = jax.jit(lambda *a: cache_append(
            *a, mesh=mesh, seq_axis="model"))(k, v, kn, vn, jnp.int32(23))
    np.testing.assert_allclose(np.asarray(kg), np.asarray(kr))
    np.testing.assert_allclose(np.asarray(vg), np.asarray(vr))


def check_ssm():
    from repro.core.seq_ssm import seq_prefix_state
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    B, H, dh, ds = 2, 3, 4, 5
    a = jax.random.uniform(ks[0], (8, B, H, 1, 1), minval=0.5, maxval=0.99)
    s = jax.random.normal(ks[1], (8, B, H, dh, ds))
    st = jnp.zeros_like(s[0])
    outs = []
    for i in range(8):
        outs.append(st)
        st = st * a[i] + s[i]
    ref = jnp.stack(outs)
    from repro.utils import shard_map
    mesh1 = make_mesh(data=1, model=8)
    with mesh1:
        f = shard_map(
            lambda a, s: seq_prefix_state(a[0], s[0], "model", 8)[None],
            mesh=mesh1, in_specs=(P("model"), P("model")),
            out_specs=P("model"))
        got = jax.jit(f)(a, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def check_models():
    from repro.configs import registry
    from repro.models.lm import transformer as T
    from repro.models.lm.modules import ShardCtx
    from repro.data.pipeline import synthetic_lm_batch
    mesh = make_mesh(data=2, model=4)
    ctx = ShardCtx(mesh=mesh, seq_axis="model", batch_axes=("data",))
    for a in ["gemma2_9b", "mixtral_8x7b", "mamba2_780m", "hymba_1_5b",
              "seamless_m4t_large_v2"]:
        cfg = registry.get(a, smoke=True)
        params = T.init(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(0, B, S, cfg.vocab).items()}
        if cfg.frontend == "audio_stub":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(1), (B, S, cfg.d_model))
        ref = T.loss_fn(params, batch, cfg, ShardCtx(), remat=False)
        with mesh:
            sb = dict(batch)
            sb["tokens"] = jax.device_put(
                batch["tokens"], NamedSharding(mesh, P("data", "model")))
            sb["labels"] = jax.device_put(
                batch["labels"], NamedSharding(mesh, P("data", "model")))
            if "frames" in sb:
                sb["frames"] = jax.device_put(
                    batch["frames"],
                    NamedSharding(mesh, P("data", "model", None)))
            got = jax.jit(lambda p, b: T.loss_fn(
                p, b, cfg, ctx, remat=False))(params, sb)
        np.testing.assert_allclose(float(got), float(ref), rtol=2e-5)
    # ring vocab-parallel CE == dense CE (fwd + grads), incl. untied + VLM
    for a in ["gemma2_9b", "pixtral_12b"]:
        cfg = registry.get(a, smoke=True)
        params = T.init(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(0, B, S, cfg.vocab).items()}
        if cfg.frontend == "vit_stub":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1), (B, cfg.frontend_len, cfg.d_model))
        ref = T.loss_fn(params, batch, cfg, ShardCtx(), remat=False)
        with mesh:
            sb = {k: jax.device_put(v, NamedSharding(
                      mesh, P("data", "model") if v.ndim == 2
                      else P("data", None, None)))
                  for k, v in batch.items()}
            got = jax.jit(lambda p, b: T.loss_fn(
                p, b, cfg, ctx, remat=False, vocab_parallel=True))(params, sb)
            g_ref = jax.grad(lambda p: T.loss_fn(
                p, batch, cfg, ShardCtx(), remat=False))(params)
            g_got = jax.jit(jax.grad(lambda p: T.loss_fn(
                p, sb, cfg, ctx, remat=False, vocab_parallel=True)))(params)
        np.testing.assert_allclose(float(got), float(ref), rtol=3e-5)
        for gr, gg in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_got)):
            np.testing.assert_allclose(np.asarray(gg), np.asarray(gr),
                                       rtol=5e-3, atol=5e-5)

    # sharded-KV decode == oracle, 2 steps
    for a in ["gemma2_9b", "qwen2_5_14b"]:
        cfg = registry.get(a, smoke=True)
        params = T.init(jax.random.PRNGKey(0), cfg)
        B = 2
        cr = T.init_decode_state(params, cfg, B, 32, dtype=jnp.float32)
        tok = jnp.array([[3], [5]], jnp.int32)
        ref, cr = T.decode_step(params, cfg, tok, cr, jnp.int32(0))
        ref2, _ = T.decode_step(params, cfg, jnp.array([[7], [9]]), cr,
                                jnp.int32(1))
        with mesh:
            cs = T.init_decode_state(params, cfg, B, 32, dtype=jnp.float32)
            cs = jax.tree.map(
                lambda x: jax.device_put(x, NamedSharding(
                    mesh, P(None, "data", "model", None, None)))
                if x.ndim == 5 else x, cs)
            f = jax.jit(lambda p, t, c, L: T.decode_step(
                p, cfg, t, c, L, ctx))
            got, cs = f(params, tok, cs, jnp.int32(0))
            got2, _ = f(params, jnp.array([[7], [9]]), cs, jnp.int32(1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2),
                                   rtol=2e-4, atol=2e-4)


def check_train():
    import shutil
    import tempfile
    from repro.core.spatial_conv import ConvSharding
    from repro.models.cnn import meshnet
    from repro.optim.optimizer import sgd
    from repro.train.train_loop import make_train_step, TrainStepConfig, \
        shard_tree
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.runtime.fault_tolerance import ResilientLoop, \
        StragglerMonitor
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.utils import FP32
    mesh = make_mesh(data=2, model=2, pod=2)
    cfg = meshnet.MeshNetConfig("tiny", input_hw=64, in_channels=4,
                                convs_per_block=1, widths=(8, 16, 16))
    sh = ConvSharding(batch_axes=("pod", "data"), h_axis="model")
    params = shard_tree(meshnet.init(jax.random.PRNGKey(0), cfg), mesh,
                        lambda x: P())
    loss = functools.partial(meshnet.loss_fn, cfg=cfg, plan=sh,
                             mesh=mesh)
    opt = sgd(0.05, momentum=0.9)
    tstep = make_train_step(
        lambda p, b: loss(p, b), opt, mesh,
        TrainStepConfig(grad_accum=2, precision=FP32,
                        pod_compression="int8_ef"))

    def put(b):
        return {"image": jax.device_put(b["image"], NamedSharding(
                    mesh, P(("pod", "data"), "model"))),
                "label": jax.device_put(b["label"], NamedSharding(
                    mesh, P(("pod", "data"),)))}

    ckdir = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(ckdir, keep=2, async_save=True)
        state = (params, opt.init(params), None)

        def make_step():
            def run(state, step):
                p, o, ef = state
                b = put(synthetic_mesh_batch(step, 8, 64, 4, out_hw=8))
                p, o, ef, m = tstep(p, o, ef, b)
                return (p, o, ef), m
            return run

        boom = {"armed": True}

        def inject(step):
            if step == 7 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("synthetic node failure")

        loop = ResilientLoop(ckpt=ck, make_step=make_step, ckpt_every=5,
                             max_failures=2)
        state, step, metrics = loop.run(state, 0, 12,
                                        monitor=StragglerMonitor(),
                                        inject_failure=inject)
        assert step == 12
        losses = []
        p, o, ef = state
        for s in range(12, 36):
            b = put(synthetic_mesh_batch(s, 8, 64, 4, out_hw=8))
            p, o, ef, m = tstep(p, o, ef, b)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], (losses[0], losses[-1])
        assert np.isfinite(losses).all()
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


def check_plan():
    """Uniform vs solved-auto NetworkPlan vs single-device oracle on a 2x2
    mesh: loss and grads agree (numerically; resharding changes fp order)."""
    from repro.core import plan as plan_lib
    from repro.core.distribution import Dist
    from repro.core.perfmodel import TPU_V5E
    from repro.core.spatial_conv import ConvSharding
    from repro.models.cnn import meshnet, resnet
    from repro.data.pipeline import synthetic_mesh_batch

    mesh = make_mesh(data=2, model=2)
    uni = ConvSharding(batch_axes=("data",), h_axis="model")

    # --- meshnet (line network, solve_line) -------------------------------
    # global-scope BN: per-shard ("local") statistics legitimately differ
    # between decompositions, so oracle comparison needs aggregated stats
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 4)
    auto = plan_lib.plan_line(TPU_V5E, specs, mesh)
    uplan = plan_lib.NetworkPlan.uniform(uni, meshnet.layer_names(cfg))
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(0, 4, 32, 4, out_hw=8).items()}
    ref_l = meshnet.loss_fn(params, b, cfg, ConvSharding())
    ref_g = jax.grad(lambda p: meshnet.loss_fn(p, b, cfg,
                                               ConvSharding()))(params)
    for plan in (uplan, auto):
        with mesh:
            got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
                p, bb, cfg, plan, mesh))(params, b)
            got_g = jax.jit(jax.grad(lambda p: meshnet.loss_fn(
                p, b, cfg, plan, mesh)))(params)
        np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
        for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=3e-4, atol=3e-5)

    # --- a genuinely mixed plan with forced reshard points ----------------
    hybrid = Dist("hybrid", {"N": ("data",), "H": ("model",)})
    sample = Dist("sample", {"N": ("data", "model")})
    mixed = plan_lib.compile_plan(
        {"conv1_1": hybrid, "conv2_1": sample, "pred": hybrid},
        specs, mesh)
    assert mixed.n_reshards == 2, mixed.describe()
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, mixed, mesh))(params, b)
        got_g = jax.jit(jax.grad(lambda p: meshnet.loss_fn(
            p, b, cfg, mixed, mesh)))(params)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
    for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)

    # --- resnet (branchy DAG, solve_dag longest-path-first) ---------------
    rcfg = resnet.ResNetConfig(name="tiny", input_hw=32, n_classes=10,
                               stages=(1, 1), widths=(8, 16),
                               bn_scope="global")
    graph = resnet.resnet_graph(2, rcfg)
    rspecs = resnet.layer_specs(2, rcfg)
    rauto = plan_lib.plan_graph(TPU_V5E, graph, rspecs, mesh)
    rparams = resnet.init(jax.random.PRNGKey(0), rcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    lbl = jnp.array([1, 7])
    rb = {"image": x, "label": lbl}
    ref_l = resnet.loss_fn(rparams, rb, rcfg, ConvSharding())
    ref_g = jax.grad(lambda p: resnet.loss_fn(p, rb, rcfg,
                                              ConvSharding()))(rparams)
    rub = plan_lib.NetworkPlan.uniform(uni, [l.name for l in rspecs])
    for plan in (rub, rauto):
        with mesh:
            got_l = jax.jit(lambda p, bb: resnet.loss_fn(
                p, bb, rcfg, plan, mesh))(rparams, rb)
            got_g = jax.jit(jax.grad(lambda p: resnet.loss_fn(
                p, rb, rcfg, plan, mesh)))(rparams)
        np.testing.assert_allclose(float(got_l), float(ref_l), rtol=3e-5)
        for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=5e-4, atol=5e-5)


def check_cf():
    """Channel/filter-parallel runtime (core.channel_conv, §III-D):
    both modes vs the dense oracle, fwd + grads, plus the Pallas
    implicit-GEMM backend in interpret mode; BN/bias; and a 4-device
    solved auto plan containing CF layers vs the single-device oracle."""
    from repro.core.channel_conv import (CFSharding, cf_batch_norm,
                                         cf_bias_add, cf_conv2d)
    from repro.core.spatial_conv import ConvSharding
    from repro.core.spatial_norm import batch_norm

    mesh = make_mesh(data=2, model=2)
    key = jax.random.PRNGKey(0)

    # --- conv parity: modes x strides x kernel sizes ----------------------
    for (K, s, C, F) in [(3, 1, 8, 12), (3, 2, 8, 8), (1, 1, 4, 8)]:
        x = jax.random.normal(key, (4, 8, 8, C), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (K, K, C, F)) * 0.1
        ref = oracle_conv(x, w, s)
        gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, s) ** 2),
                      argnums=(0, 1))(x, w)
        for mode in ("channel", "filter"):
            sh = CFSharding(batch_axes=("data",), cf_axis="model",
                            mode=mode)
            with mesh:
                got = jax.jit(lambda x, w: cf_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh))(x, w)
                gd = jax.jit(jax.grad(lambda x, w: jnp.sum(cf_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh) ** 2),
                    argnums=(0, 1)))(x, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            for a, b in zip(gd, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-4, atol=3e-4)

    # --- the §IV-A chunked channel-block split (overlapped channel mode,
    # the TPU default) pinned explicitly: parity incl. grads -------------
    x = jax.random.normal(key, (4, 8, 8, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 8)) * 0.1
    sh = CFSharding(batch_axes=("data",), cf_axis="model")
    ref = oracle_conv(x, w, 1)
    gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, 1) ** 2),
                  argnums=(0, 1))(x, w)
    for chunks in (2, 3):
        with mesh:
            got = jax.jit(lambda x, w: cf_conv2d(
                x, w, sharding=sh, mesh=mesh,
                channel_chunks=chunks))(x, w)
            gd = jax.jit(jax.grad(lambda x, w: jnp.sum(cf_conv2d(
                x, w, sharding=sh, mesh=mesh,
                channel_chunks=chunks) ** 2), argnums=(0, 1)))(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        for a, b in zip(gd, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-4, atol=3e-4)

    # --- the Pallas implicit-GEMM kernel through the CF path (interpret
    # mode on CPU — numerics-identical to the TPU lowering) ----------------
    with mesh:
        got = jax.jit(lambda x, w: cf_conv2d(
            x, w, sharding=sh, mesh=mesh, backend="pallas"))(x, w)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(oracle_conv(x, w, 1)),
                               rtol=2e-5, atol=2e-5)

    # --- BN: per-channel stats never cross the CF axis --------------------
    x = jax.random.normal(key, (4, 8, 8, 8), jnp.float32) * 3 + 1
    g = jax.random.normal(jax.random.PRNGKey(2), (8,)) + 2
    b = jax.random.normal(jax.random.PRNGKey(3), (8,))
    ref = batch_norm(x, g, b, sharding=ConvSharding(), scope="local")
    with mesh:
        got = jax.jit(lambda x: cf_batch_norm(
            x, g, b, sharding=sh, mesh=mesh, scope="global"))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    with mesh:
        got = jax.jit(lambda x: cf_bias_add(x, b, sharding=sh,
                                            mesh=mesh))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x + b),
                               rtol=1e-6, atol=1e-6)

    # --- acceptance: a solved 4-device auto plan with >= 1 CF layer
    # matches the single-device oracle (loss + grads) ----------------------
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet

    # late layers: h=4 < k=3 — no spatial split fits, channels do (§III-D)
    cfg = meshnet.MeshNetConfig("t", input_hw=16, in_channels=8,
                                convs_per_block=1, widths=(16, 32, 32),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 2)
    auto = plan_lib.plan_line(TPU_V5E, specs, mesh)
    n_cf = sum(isinstance(lp.sharding, CFSharding)
               for lp in auto.layers.values())
    assert n_cf >= 1, auto.describe()
    assert auto.n_reshards >= 1, auto.describe()   # CF <-> spatial shuffle

    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_mesh_batch(0, 2, 16, 8, out_hw=2).items()}
    ref_l = meshnet.loss_fn(params, batch, cfg, ConvSharding())
    ref_g = jax.grad(lambda p: meshnet.loss_fn(
        p, batch, cfg, ConvSharding()))(params)
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, auto, mesh))(params, batch)
        got_g = jax.jit(jax.grad(lambda p: meshnet.loss_fn(
            p, batch, cfg, auto, mesh)))(params)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
    for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)

    # --- consecutive CF layers chain with zero resharding -----------------
    cf = {"C": ("model",), "F": ("model",), "N": ("data",)}
    from repro.core.distribution import Dist
    forced = plan_lib.compile_plan(
        {"conv1_1": Dist("hybrid", {"N": ("data",), "H": ("model",)}),
         "conv2_1": Dist("channel_filter", cf),
         "conv3_1": Dist("channel_filter", cf),
         "pred": Dist("sample", {"N": ("data",)})},
        specs, mesh)
    lps = forced.layers
    assert lps["conv2_1"].reshard_in and not lps["conv3_1"].reshard_in
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, forced, mesh))(params, batch)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)


def check_spatial2d():
    """W-axis and 2-D (H x W) spatial decompositions: conv fwd + grads,
    pooling, and a compiled plan with W-splits vs the oracle (the ROADMAP
    item on exercising the 2-D decomposition)."""
    from repro.core.spatial_conv import spatial_conv2d, spatial_pool, \
        ConvSharding

    mesh = make_mesh(data=2, model=2)
    key = jax.random.PRNGKey(0)
    shw = ConvSharding(batch_axes=("model",), w_axis="data")   # W only
    sh2 = ConvSharding(batch_axes=(), h_axis="model", w_axis="data")
    for sh in (shw, sh2):
        for (K, s) in [(3, 1), (3, 2), (7, 2)]:
            x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
            w = jax.random.normal(jax.random.PRNGKey(1),
                                  (K, K, 3, 5)) * 0.1
            ref = oracle_conv(x, w, s)
            gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, s) ** 2),
                          argnums=(0, 1))(x, w)
            for overlap in (False, True):
                with mesh:
                    got = jax.jit(lambda x, w: spatial_conv2d(
                        x, w, strides=(s, s), sharding=sh, mesh=mesh,
                        overlap=overlap))(x, w)
                    gd = jax.jit(jax.grad(
                        lambda x, w: jnp.sum(spatial_conv2d(
                            x, w, strides=(s, s), sharding=sh, mesh=mesh,
                            overlap=overlap) ** 2),
                        argnums=(0, 1)))(x, w)
                np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                           rtol=2e-5, atol=2e-5)
                for a, b in zip(gd, gr):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=3e-4, atol=3e-4)
        # pooling under W / H x W splits (max needs the -inf edge halo)
        x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
        for kind in ("max", "avg"):
            ref = spatial_pool(x, window=(3, 3), strides=(2, 2),
                               sharding=ConvSharding(), kind=kind)
            with mesh:
                got = jax.jit(lambda x: spatial_pool(
                    x, window=(3, 3), strides=(2, 2), sharding=sh,
                    mesh=mesh, kind=kind))(x)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-6, atol=1e-6)

    # a compiled plan whose dists shard W — through the full model stack
    from repro.core import plan as plan_lib
    from repro.core.distribution import Dist
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 4)
    plan = plan_lib.compile_plan(
        {"conv1_1": Dist("s2d", {"H": ("model",), "W": ("data",)}),
         "conv2_1": Dist("wsplit", {"N": ("model",), "W": ("data",)}),
         "pred": Dist("hybrid", {"N": ("data",), "H": ("model",)})},
        specs, mesh)
    assert plan.n_reshards == 2, plan.describe()
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(0, 4, 32, 4, out_hw=8).items()}
    ref_l = meshnet.loss_fn(params, b, cfg, ConvSharding())
    ref_g = jax.grad(lambda p: meshnet.loss_fn(p, b, cfg,
                                               ConvSharding()))(params)
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, plan, mesh))(params, b)
        got_g = jax.jit(jax.grad(lambda p: meshnet.loss_fn(
            p, b, cfg, plan, mesh)))(params)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
    for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)


def check_multiaxis():
    """Multi-axis spatial + CF x spatial composition on an 8-device mesh
    reshaped to (2, 2, 2) — the 16x16-mesh decompositions at test scale:
    halo exchange over a *product* of mesh axes, the CF collective and the
    halo in one shard_map (both modes, overlapped and not, Pallas interpret
    backend), pooling/BN over product axes, and the acceptance check — a
    solved auto plan containing >= 1 multi-axis-H layer and >= 1
    CF x spatial layer matches the single-device oracle (fwd + grads)."""
    from repro.core.channel_conv import CFSharding, cf_batch_norm, cf_conv2d
    from repro.core.spatial_conv import (ConvSharding, spatial_conv2d,
                                         spatial_pool)
    from repro.core.spatial_norm import batch_norm

    mesh = make_mesh(data=2, model=2, pod=2)
    key = jax.random.PRNGKey(0)

    # --- conv under H split over the ('data','model') product axis --------
    sh = ConvSharding(batch_axes=("pod",), h_axis=("data", "model"))
    for (K, s, H, W) in [(3, 1, 16, 8), (3, 2, 16, 16), (7, 2, 32, 8),
                         (1, 1, 8, 8)]:
        x = jax.random.normal(key, (2, H, W, 3), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (K, K, 3, 5)) * 0.1
        ref = oracle_conv(x, w, s)
        gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, s) ** 2),
                      argnums=(0, 1))(x, w)
        for overlap in (False, True):
            with mesh:
                got = jax.jit(lambda x, w: spatial_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh,
                    overlap=overlap))(x, w)
                gd = jax.jit(jax.grad(lambda x, w: jnp.sum(spatial_conv2d(
                    x, w, strides=(s, s), sharding=sh, mesh=mesh,
                    overlap=overlap) ** 2), argnums=(0, 1)))(x, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            for a, b in zip(gd, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-4, atol=3e-4)

    # --- 2-D decomposition where one dim is a product: H x (W product) ----
    sh2 = ConvSharding(batch_axes=(), h_axis="model",
                       w_axis=("pod", "data"))
    x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.1
    with mesh:
        got = jax.jit(lambda x, w: spatial_conv2d(
            x, w, sharding=sh2, mesh=mesh))(x, w)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(oracle_conv(x, w, 1)),
                               rtol=2e-5, atol=2e-5)

    # --- pooling and BN over the product axis -----------------------------
    x = jax.random.normal(key, (2, 16, 8, 6), jnp.float32) * 3 + 1
    for kind in ("max", "avg"):
        ref = spatial_pool(x, window=(3, 3), strides=(2, 2),
                           sharding=ConvSharding(), kind=kind)
        with mesh:
            got = jax.jit(lambda x: spatial_pool(
                x, window=(3, 3), strides=(2, 2), sharding=sh, mesh=mesh,
                kind=kind))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    g = jnp.ones((6,)); b = jnp.zeros((6,))
    ref = batch_norm(x, g, b, sharding=ConvSharding(), scope="local")
    with mesh:
        got = jax.jit(lambda x: batch_norm(
            x, g, b, sharding=sh, mesh=mesh, scope="global"))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    # --- CF x spatial: halo + CF collective in ONE shard_map --------------
    x = jax.random.normal(key, (2, 16, 8, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 12)) * 0.1
    ref = oracle_conv(x, w, 1)
    gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, 1) ** 2),
                  argnums=(0, 1))(x, w)
    for mode in ("channel", "filter"):
        for overlap in (False, True):
            shc = CFSharding(batch_axes=(), cf_axis="model", mode=mode,
                             h_axis=("pod", "data"))
            with mesh:
                got = jax.jit(lambda x, w: cf_conv2d(
                    x, w, sharding=shc, mesh=mesh, overlap=overlap))(x, w)
                gd = jax.jit(jax.grad(lambda x, w: jnp.sum(cf_conv2d(
                    x, w, sharding=shc, mesh=mesh, overlap=overlap) ** 2),
                    argnums=(0, 1)))(x, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            for a, b in zip(gd, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-4, atol=3e-4)

    # CF x spatial BN: per-channel stats cross the spatial axes now
    shc = CFSharding(batch_axes=(), cf_axis="model", h_axis=("pod", "data"))
    xb = jax.random.normal(key, (2, 16, 8, 8), jnp.float32) * 3 + 1
    gb = jnp.ones((8,)); bb = jnp.zeros((8,))
    ref = batch_norm(xb, gb, bb, sharding=ConvSharding(), scope="local")
    with mesh:
        got = jax.jit(lambda x: cf_batch_norm(
            x, gb, bb, sharding=shc, mesh=mesh, scope="global"))(xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    # --- the Pallas implicit-GEMM backend through the composed path
    # (interpret mode on CPU — numerics-identical to the TPU lowering) -----
    with mesh:
        got = jax.jit(lambda x, w: spatial_conv2d(
            x, w, sharding=ConvSharding(batch_axes=("pod",),
                                        h_axis=("data", "model")),
            mesh=mesh, backend="pallas"))(x, w)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(oracle_conv(x, w, 1)),
                               rtol=2e-5, atol=2e-5)

    # --- acceptance: a solved auto plan on the (2,2,2) mesh with >= 1
    # multi-axis-H layer and >= 1 CF x spatial layer vs the oracle ---------
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet

    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=8,
                                convs_per_block=1, widths=(16, 32, 64),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 2)
    auto = plan_lib.plan_line(TPU_V5E, specs, mesh)
    n_multi = sum(len(lp.sharding.h_axes) > 1 or len(lp.sharding.w_axes) > 1
                  for lp in auto.layers.values())
    n_cfsp = sum(isinstance(lp.sharding, CFSharding)
                 and lp.sharding.cf_axis is not None
                 and lp.sharding.is_spatial
                 for lp in auto.layers.values())
    assert n_multi >= 1, auto.describe()
    assert n_cfsp >= 1, auto.describe()

    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_mesh_batch(0, 2, 32, 8, out_hw=4).items()}
    ref_l = meshnet.loss_fn(params, batch, cfg, ConvSharding())
    ref_g = jax.grad(lambda p: meshnet.loss_fn(
        p, batch, cfg, ConvSharding()))(params)
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, auto, mesh))(params, batch)
        got_g = jax.jit(jax.grad(lambda p: meshnet.loss_fn(
            p, batch, cfg, auto, mesh)))(params)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
    for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)

    # a forced mixed plan crossing single-axis, product-axis and CF x
    # spatial layers: each transition is one §III-C reshard point
    from repro.core.distribution import Dist
    forced = plan_lib.compile_plan(
        {"conv1_1": Dist("hyb", {"N": ("pod",), "H": ("data", "model")}),
         "conv2_1": Dist("cfh", {"N": ("pod",), "H": ("data",),
                                 "C": ("model",), "F": ("model",)}),
         "conv3_1": Dist("hyb1", {"N": ("data",), "H": ("model",)}),
         "pred": Dist("wprod", {"N": ("pod",), "W": ("data", "model")})},
        specs, mesh)
    assert forced.n_reshards == 3, forced.describe()
    with mesh:
        got_l = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, forced, mesh))(params, batch)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)


def check_memfit():
    """Memory-aware planning acceptance (paper §VI, Table 2): on a 2x2
    host mesh with a synthetic per-device capacity limit chosen so the
    uniform sample-parallel plan cannot fit (batch < devices: sample
    parallelism cannot reduce per-device memory below one sample), the
    --mem-limit solve returns a spatial/hybrid plan whose modeled peak
    fits, whose XLA-measured peak agrees with the model within the
    property-test tolerance (2x), and which executes fwd + bwd matching
    the single-device oracle."""
    from repro.core import calibrate as calib
    from repro.core import plan as plan_lib
    from repro.core.distribution import Dist
    from repro.core.perfmodel import TPU_V5E, network_memory
    from repro.core.spatial_conv import ConvSharding
    from repro.core.strategy import CapacityError, prune_by_memory
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet

    mesh = make_mesh(data=2, model=2)
    ms = dict(mesh.shape)
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16),
                                bn_scope="global")
    BATCH = 2        # < 4 devices: sample parallelism caps at 2-way
    specs = meshnet.layer_specs(cfg, BATCH)

    # the best sample-only residency (2-way N) must NOT fit the limit
    sample = [Dist("sample", {"N": ("data",)})] * len(specs)
    sample_peak = network_memory(TPU_V5E, specs, sample, ms)["peak_bytes"]
    limit = 0.75 * sample_peak
    assert sample_peak > limit

    plan = plan_lib.plan_line(TPU_V5E, specs, mesh, mem_limit=limit)
    mem = plan.predicted["memory"]
    assert mem["peak_bytes"] <= limit, plan.describe()
    assert mem["limit_bytes"] == limit
    # the fit must have been bought with spatial decomposition
    assert any(lp.sharding.is_spatial for lp in plan.layers.values()), \
        plan.describe()

    # a hopeless limit raises CapacityError with footprint diagnostics
    try:
        prune_by_memory(TPU_V5E, specs[0],
                        [Dist("sample", {"N": ("data",)})], ms, 64.0)
        raise AssertionError("expected CapacityError")
    except CapacityError as e:
        assert "conv1_1" in str(e) and "act_in" in str(e), e

    # XLA cross-check + oracle equivalence of the executed plan
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_mesh_batch(0, BATCH, 32, 4, out_hw=8).items()}
    ref_l = meshnet.loss_fn(params, batch, cfg, ConvSharding())
    ref_g = jax.grad(lambda p: meshnet.loss_fn(
        p, batch, cfg, ConvSharding()))(params)
    first = specs[0]
    with mesh:
        spec = plan.input_spec(first.name, first.h, first.w, first.k,
                               first.s, mesh)
        bb = dict(batch)
        bb["image"] = jax.device_put(batch["image"],
                                     NamedSharding(mesh, spec))
        step = jax.jit(jax.value_and_grad(
            lambda p, b: meshnet.loss_fn(p, b, cfg, plan, mesh)))
        res = calib.crosscheck_memory(plan, step, params, bb)
        assert 0.5 <= res["ratio"] <= 2.0, res
        got_l, got_g = step(params, bb)
    np.testing.assert_allclose(float(got_l), float(ref_l), rtol=2e-5)
    for a, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)
    print(f"memfit: limit {limit:.0f}B, sample {sample_peak:.0f}B (out), "
          f"solved {mem['peak_bytes']:.0f}B (fits), "
          f"xla ratio {res['ratio']:.2f}")


def check_overlap():
    """The §IV-A latency-hiding schedule is a pure reorder: on a 4-device
    mesh the interior/boundary split (overlap=True) matches both the
    serialized path (overlap=False) and the single-device oracle, forward
    and grads, on the XLA and the Pallas-interpret local-conv backends —
    and the optimization_barrier pin survives jit (it is findable in the
    lowered HLO, so XLA cannot re-serialize the schedule behind our back).
    """
    from repro.core.spatial_conv import spatial_conv2d, ConvSharding

    mesh = make_mesh(data=2, model=2)
    key = jax.random.PRNGKey(0)
    sh = ConvSharding(batch_axes=("data",), h_axis="model")
    # shards tall enough that the interior/boundary split engages
    # (h_local=16 vs k): plain k=3 and a strided k=5 geometry
    for (K, s, H, W, C, F) in [(3, 1, 32, 12, 5, 7), (5, 2, 32, 16, 3, 8)]:
        x = jax.random.normal(key, (4, H, W, C), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (K, K, C, F)) * 0.1
        ref = oracle_conv(x, w, s)
        gr = jax.grad(lambda x, w: jnp.sum(oracle_conv(x, w, s) ** 2),
                      argnums=(0, 1))(x, w)
        for backend in ("xla", "pallas"):
            with mesh:
                def fn(x, w, ov):
                    return spatial_conv2d(
                        x, w, strides=(s, s), sharding=sh, mesh=mesh,
                        overlap=ov, backend=backend)
                got_ov = jax.jit(functools.partial(fn, ov=True))(x, w)
                got_ser = jax.jit(functools.partial(fn, ov=False))(x, w)
                np.testing.assert_allclose(np.asarray(got_ov),
                                           np.asarray(ref),
                                           rtol=2e-5, atol=2e-5)
                # overlap on/off is the same math in a different order
                np.testing.assert_allclose(np.asarray(got_ov),
                                           np.asarray(got_ser),
                                           rtol=2e-5, atol=2e-5)
                if backend == "xla":
                    # grads ride the XLA local conv on legacy jax (the
                    # Pallas path is forward-verified; see utils.shard_map)
                    gd = jax.jit(jax.grad(
                        lambda x, w: jnp.sum(spatial_conv2d(
                            x, w, strides=(s, s), sharding=sh, mesh=mesh,
                            overlap=True, backend=backend) ** 2),
                        argnums=(0, 1)))(x, w)
                    for a, b in zip(gd, gr):
                        np.testing.assert_allclose(np.asarray(a),
                                                   np.asarray(b),
                                                   rtol=3e-4, atol=3e-4)

    # the HaloSchedule pin must survive jit: the lowered module contains
    # the opt-barrier that orders boundary convs after the interior conv
    x = jax.random.normal(key, (4, 32, 12, 5), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 5, 7)) * 0.1
    with mesh:
        jitted = jax.jit(lambda x, w: spatial_conv2d(
            x, w, sharding=sh, mesh=mesh, overlap=True))
        hlo = jitted.lower(x, w).as_text()
        assert "optimization_barrier" in hlo, \
            "optimization_barrier pin lost in lowering"
        ser = jax.jit(lambda x, w: spatial_conv2d(
            x, w, sharding=sh, mesh=mesh, overlap=False))
        assert "optimization_barrier" not in ser.lower(x, w).as_text(), \
            "serialized path must not carry the schedule pin"
    print("overlap: schedule parity (xla + pallas-interpret) OK, "
          "opt-barrier pinned through jit")


def check_trace():
    """Plan-aware tracing (core.trace) on a 4-device solved plan: the
    segmented re-execution profiler attributes every plan layer with a
    positive measured fwd+bwd cost, the isolated per-layer sums land
    within dispatch-overhead tolerance of the whole fused step, the
    attribution join (plan.attribution_report) covers every layer and
    names a worst-drifting cost term, and the layer/region annotations
    survive into the *compiled* HLO op_name metadata (named_scope names
    are absent from the StableHLO lowering on this jax — the compiled
    module is where profiles become decodable)."""
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.core.trace import StepTrace, trace_plan
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet

    mesh = make_mesh(data=2, model=2)
    cfg = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                                convs_per_block=1, widths=(8, 16, 16),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, 2)
    plan = plan_lib.plan_line(TPU_V5E, specs, mesh)
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in
         synthetic_mesh_batch(0, 2, 32, 4, out_hw=4).items()}
    first = specs[0]
    spec = plan.input_spec(first.name, first.h, first.w, first.k,
                           first.s, mesh)
    b["image"] = jax.device_put(b["image"], NamedSharding(mesh, spec))
    b["label"] = jax.device_put(b["label"], NamedSharding(mesh, P("data")))
    trace = trace_plan(plan, params, b, cfg=cfg, mesh=mesh,
                       reps=2, rounds=2)

    names = meshnet.layer_names(cfg)
    assert list(trace.layers) == names, list(trace.layers)
    for name, r in trace.layers.items():
        assert r["fwd_s"] > 0, (name, r)
        assert r["fwd_bwd_s"] >= r["fwd_s"] * 0.5, (name, r)
        assert r["bwd_s"] >= 0, (name, r)
    # segmentation-overhead bound: the isolated sums track the fused step
    # (isolated layers lose cross-layer fusion and pay extra dispatch, so
    # the bound is loose — catching 100x pathologies, not noise)
    ratio = trace.layer_sum_s / trace.step["fwd_bwd_s"]
    assert 0.1 <= ratio <= 10.0, (ratio, trace.layers, trace.step)
    assert trace.meta["measured_peak_bytes"] > 0
    assert StepTrace.from_dict(trace.to_dict()).to_dict() == trace.to_dict()

    # the attribution join covers every plan layer and names a worst term
    rep = plan.attribution_report(trace)
    assert set(rep["per_layer"]) == set(names), rep["per_layer"].keys()
    assert rep["worst_term"] in rep["terms"], rep
    assert rep["totals"]["measured_s"] > 0

    # annotations land in the COMPILED HLO metadata (op_name)
    with mesh:
        txt = jax.jit(lambda p, bb: meshnet.loss_fn(
            p, bb, cfg, plan, mesh)).lower(params, b).compile().as_text()
    for needle in names:
        assert needle in txt, f"layer scope {needle!r} not in compiled HLO"
    assert ("conv_interior" in txt or "conv_serialized" in txt
            or "cf_all_gather" in txt or "cf_reduce_scatter" in txt), \
        "no conv region annotation in compiled HLO"
    print(f"trace: {len(names)} layers attributed, layer_sum/step "
          f"{ratio:.2f}, worst term {rep['worst_term']}")


def check_compress():
    from repro.optim.grad_compress import cross_pod_mean
    mesh = make_mesh(data=2, model=2, pod=2)
    key = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(key, (64, 32)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (128,))}
    with mesh:
        out_none, _ = jax.jit(lambda g: cross_pod_mean(
            g, mesh=mesh, method="none"))(g)
        out_bf16, _ = jax.jit(lambda g: cross_pod_mean(
            g, mesh=mesh, method="bf16"))(g)
    # replicated input => mean == input
    np.testing.assert_allclose(np.asarray(out_none["a"]),
                               np.asarray(g["a"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out_bf16["a"]),
                               np.asarray(g["a"]), rtol=2e-2, atol=2e-2)
    # int8 + EF: quantization error is carried, not lost — two applications
    # of the same gradient converge toward it on average
    ef = None
    with mesh:
        f = jax.jit(lambda g, ef: cross_pod_mean(
            g, mesh=mesh, method="int8_ef", error_feedback=ef))
        out1, ef = f(g, ef)
        out2, ef = f(g, ef)
    err1 = float(jnp.abs(out1["a"] - g["a"]).mean())
    two_step = (np.asarray(out1["a"]) + np.asarray(out2["a"])) / 2
    err2 = float(np.abs(two_step - np.asarray(g["a"])).mean())
    assert err2 < err1 + 1e-7, (err1, err2)
    assert err1 < 0.05  # int8 quantization error is small


def check_elastic():
    """The chaos-lane acceptance (ISSUE PR-8): a 4-device training run is
    faulted mid-run and must recover with a loss trajectory matching the
    uninterrupted oracle.  Three fault modes, selected by $CHAOS_MODE:

      step-fault   raise at step 7, same-mesh rollback to the step-6
                   checkpoint; post-restore losses match bitwise-ish
      kill-device  drop 1 of 4 devices at step 7 (DeviceLoss) -> elastic
                   remesh onto the 3 survivors, plan recovered from the
                   checkpoint's repro/plan@1 record (plan_from_spec, with
                   the designed PlanError -> fresh re-solve fallback),
                   reshard-on-restore, resume; losses match numerically
      corrupt-tmp  plant mid-save debris (torn tmp dir + malformed step
                   entry) then fault: latest_step must ignore the garbage,
                   rollback picks the valid step-6, gc sweeps the tmp

    With $CHAOS_ARTIFACTS_DIR set, the checkpoint dir, metrics JSONL and
    loss trajectories land there (CI uploads them on failure)."""
    import json
    import shutil
    import tempfile
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.data.pipeline import synthetic_mesh_batch
    from repro.models.cnn import meshnet
    from repro.launch.mesh import elastic_factorization
    from repro.optim.optimizer import sgd
    from repro.runtime import chaos
    from repro.runtime.fault_tolerance import ResilientLoop, \
        StragglerMonitor
    from repro.train.metrics import MetricsLogger
    from repro.train.train_loop import make_train_step, TrainStepConfig, \
        shard_tree
    from repro.utils import FP32

    mode = os.environ.get("CHAOS_MODE", "kill-device")
    assert mode in ("step-fault", "kill-device", "corrupt-tmp"), mode
    NUM, EVERY, FAULT, BATCH = 10, 3, 7, 4
    devices = jax.devices()[:4]
    mesh4 = make_mesh(data=2, model=2, devices=devices)
    cfg = meshnet.MeshNetConfig("t", input_hw=24, in_channels=6,
                                convs_per_block=1, widths=(12, 24),
                                bn_scope="global")
    specs = meshnet.layer_specs(cfg, BATCH)
    opt = sgd(0.05, momentum=0.9)

    # a capacity limit both the 4-device and the shrunk 3-device solve can
    # meet — the elastic restart re-solves under the SAME limit
    peak4 = plan_lib.plan_line(TPU_V5E, specs, mesh4) \
        .predicted["memory"]["peak_bytes"]
    peak3 = plan_lib.plan_line(TPU_V5E, specs, {"data": 1, "model": 3}) \
        .predicted["memory"]["peak_bytes"]
    limit = 1.25 * max(peak4, peak3)
    plan4 = plan_lib.plan_line(TPU_V5E, specs, mesh4, mem_limit=limit)

    def init_state(mesh):
        # a fresh state every time: the train step DONATES its buffers,
        # so the oracle run and the faulted run cannot share arrays
        params = shard_tree(meshnet.init(jax.random.PRNGKey(0), cfg),
                            mesh, lambda x: P())
        return shard_tree((params, opt.init(params), None),
                          mesh, lambda x: P())

    def make_rig(mesh, plan):
        loss = functools.partial(meshnet.loss_fn, cfg=cfg, plan=plan,
                                 mesh=mesh)
        tstep = make_train_step(lambda p, b: loss(p, b), opt, mesh,
                                TrainStepConfig(precision=FP32))
        first = specs[0]
        spec = plan.input_spec(first.name, first.h, first.w, first.k,
                               first.s, mesh)

        def put(b):
            return {"image": jax.device_put(
                        b["image"], NamedSharding(mesh, spec)),
                    "label": jax.device_put(
                        b["label"], NamedSharding(mesh, P("data")))}
        return tstep, put

    tstep4, put4 = make_rig(mesh4, plan4)

    # --- the uninterrupted oracle -----------------------------------------
    oracle = []
    p, o, ef = init_state(mesh4)
    for s in range(NUM):
        b = put4(synthetic_mesh_batch(s, BATCH, cfg.input_hw,
                                      cfg.in_channels, out_hw=cfg.out_hw))
        p, o, ef, m = tstep4(p, o, ef, b)
        oracle.append(float(m["loss"]))

    # --- the faulted run --------------------------------------------------
    art = os.environ.get("CHAOS_ARTIFACTS_DIR")
    base = art or tempfile.mkdtemp()
    os.makedirs(base, exist_ok=True)
    ckdir = os.path.join(base, "ckpt")
    metrics_path = os.path.join(base, "metrics.jsonl")
    try:
        ck = CheckpointManager(ckdir, keep=3, async_save=True)
        mlog = MetricsLogger(metrics_path, echo=False)
        plan_spec = plan4.to_spec(mesh4, mem_limit=limit, config_hash="t",
                                  calibration_fingerprint=None)
        ctx = {"tstep": tstep4, "put": put4, "plan_spec": plan_spec}
        got: dict[int, float] = {}

        def make_step():
            def run(state, step):
                p, o, ef = state
                b = ctx["put"](synthetic_mesh_batch(
                    step, BATCH, cfg.input_hw, cfg.in_channels,
                    out_hw=cfg.out_hw))
                p, o, ef, m = ctx["tstep"](p, o, ef, b)
                got[step] = float(m["loss"])
                return (p, o, ef), m
            return run

        def remesh(survivors):
            assert len(survivors) == 3, survivors
            data, model = elastic_factorization(len(survivors),
                                                batch=BATCH)
            mesh3 = make_mesh(data=data, model=model,
                              devices=list(survivors))
            rec = ck.read_manifest()["plan"]
            assert rec["schema"] == plan_lib.PLAN_SCHEMA, rec
            assert rec["mesh"] == {"data": 2, "model": 2}, rec
            try:
                plan3 = plan_lib.plan_from_spec(
                    rec, specs, mesh3, machine=TPU_V5E,
                    mem_limit=rec["mem_limit"])
            except plan_lib.PlanError:
                # the stored dists don't lower onto the shrunk mesh —
                # the designed fallback is a fresh solve, SAME limit
                plan3 = plan_lib.plan_line(TPU_V5E, specs, mesh3,
                                           mem_limit=rec["mem_limit"])
            assert plan3.predicted["memory"]["peak_bytes"] <= \
                rec["mem_limit"], plan3.describe()
            tstep3, put3 = make_rig(mesh3, plan3)
            template3 = init_state(mesh3)
            ctx.update(tstep=tstep3, put=put3,
                       plan_spec=plan3.to_spec(
                           mesh3, mem_limit=rec["mem_limit"],
                           config_hash="t",
                           calibration_fingerprint=None))
            return make_step, template3

        if mode == "step-fault":
            inject = chaos.raise_at_step(FAULT)
            use_remesh = None
        elif mode == "kill-device":
            inject = chaos.drop_device_at_step(FAULT, devices=devices)
            use_remesh = remesh
        else:
            inject = chaos.compose(
                chaos.corrupt_checkpoint_tmp(ckdir, FAULT - 3),
                chaos.raise_at_step(FAULT))
            use_remesh = None

        loop = ResilientLoop(ckpt=ck, make_step=make_step,
                             ckpt_every=EVERY, max_failures=2,
                             remesh=use_remesh, metrics=mlog,
                             plan_spec=lambda: ctx["plan_spec"])
        state, step, _ = loop.run(init_state(mesh4), 0, NUM,
                                  monitor=StragglerMonitor(),
                                  inject_failure=inject)
        mlog.close()
        assert step == NUM, step
        assert sorted(got) == list(range(NUM)), sorted(got)

        with open(os.path.join(base, "losses.json"), "w") as f:
            json.dump({"oracle": oracle,
                       "got": [got[s] for s in range(NUM)]}, f)

        events = [json.loads(ln) for ln in open(metrics_path)]
        kinds = [e["kind"] for e in events]
        assert "fault" in kinds, kinds
        rollbacks = [e for e in events if e["kind"] == "rollback"]
        assert rollbacks and rollbacks[0]["step"] == FAULT - 1, rollbacks

        # pre-fault steps ran once on the original mesh: exact agreement
        np.testing.assert_allclose(
            [got[s] for s in range(FAULT - 1)], oracle[:FAULT - 1],
            rtol=1e-6)
        post = [got[s] for s in range(FAULT - 1, NUM)]
        if mode == "kill-device":
            assert "remesh" in kinds, kinds
            rm = next(e for e in events if e["kind"] == "remesh")
            assert rm["n_devices"] == 3, rm
            # the 3-device decomposition reorders the fp math — numeric,
            # not bitwise, agreement with the oracle trajectory
            np.testing.assert_allclose(post, oracle[FAULT - 1:],
                                       rtol=5e-3)
        else:
            np.testing.assert_allclose(post, oracle[FAULT - 1:],
                                       rtol=1e-5)
        if mode == "corrupt-tmp":
            left = os.listdir(ckdir)
            assert not [d for d in left if d.startswith("tmp-")], left
            assert "step-garbage" in left, left       # ignored, not fatal
            assert ck.latest_step() == NUM - 1, (ck.latest_step(), left)
        print(f"elastic[{mode}]: recovered at step {FAULT - 1}, "
              f"{NUM} steps, max post-restore drift "
              f"{max(abs(a - b) for a, b in zip(post, oracle[FAULT - 1:])):.2e}")
    finally:
        if not art:
            shutil.rmtree(base, ignore_errors=True)


def check_audit():
    """Property: EVERY executable candidate dist, over several mesh
    factorizations and layer shapes, lowers and audits clean on the XLA
    backend — zero unpriced collectives, zero phantom charges (no
    error-severity finding at all).  This is the pin that keeps
    perfmodel.layer_collectives (the priced inventory) and the runtime's
    actual shard_map lowerings from drifting apart."""
    from repro import analysis
    from repro.core import perfmodel as pm
    from repro.core import plan as plan_lib
    from repro.core import trace as trace_lib
    from repro.models.cnn import layers as L

    shapes = [
        pm.ConvLayer("probe", n=4, c=8, h=16, w=16, f=8),          # vanilla
        pm.ConvLayer("probe", n=1, c=16, h=16, w=16, f=16, s=2),   # stride 2
        pm.ConvLayer("probe", n=2, c=12, h=8, w=8, f=6, k=1),      # 1x1, c=12
        pm.ConvLayer("probe", n=2, c=4, h=32, w=8, f=8),           # tall
        pm.ConvLayer("probe", n=8, c=8, h=8, w=8, f=32),           # batch-rich
        pm.ConvLayer("probe", n=1, c=32, h=4, w=4, f=32),          # CF terrain
    ]
    checked = 0
    for data, model in [(2, 4), (4, 2), (1, 8), (8, 1)]:
        mesh = make_mesh(data=data, model=model)
        for spec in shapes:
            for dist in plan_lib.executable_candidates(spec,
                                                       dict(mesh.shape)):
                plan = plan_lib.compile_plan({spec.name: dist}, [spec],
                                             mesh)
                sh = plan.sharding(spec.name)
                params = {"w": jax.ShapeDtypeStruct(
                    (spec.k, spec.k, spec.c, spec.f), jnp.float32)}
                x = jax.ShapeDtypeStruct((spec.n, spec.h, spec.w, spec.c),
                                         jnp.float32)

                def loss(p, xx, sh=sh, spec=spec):
                    with trace_lib.layer_context(spec.name):
                        y = L.conv_apply(p, xx, stride=spec.s, sharding=sh,
                                         mesh=mesh, overlap=True)
                    return jnp.sum(y * y)

                findings = analysis.audit_step_fn(
                    jax.value_and_grad(loss, argnums=(0, 1)), (params, x),
                    plan, [spec], mesh, overlap=True, hlo=False,
                    grad_wrt_inputs=True)
                bad = [f for f in findings if f.severity == "error"]
                assert not bad, (
                    f"mesh data={data} model={model} "
                    f"layer={spec} dist={dist}: " +
                    "; ".join(f"{f.rule}: {f.message}" for f in bad))
                checked += 1
    print(f"audit: {checked} (mesh x shape x dist) lowerings audit clean")

    # --- negative direction: a broken program MUST fire the named rule ---
    from repro.core.spatial_conv import ConvSharding
    from repro.utils import shard_map
    mesh = make_mesh(data=2, model=4)
    spec = pm.ConvLayer("probe", n=4, c=8, h=16, w=16, f=8)
    dist = plan_lib._sharding_to_dist(
        ConvSharding(batch_axes=("data",), h_axis="model"))
    plan = plan_lib.compile_plan({spec.name: dist}, [spec], mesh)
    sh = plan.sharding(spec.name)
    params = {"w": jax.ShapeDtypeStruct((3, 3, spec.c, spec.f),
                                        jnp.float32)}
    x = jax.ShapeDtypeStruct((spec.n, spec.h, spec.w, spec.c), jnp.float32)

    # 1) inject a collective the model never priced -> unpriced-collective
    def loss_inj(p, xx):
        with trace_lib.layer_context(spec.name):
            y = L.conv_apply(p, xx, stride=1, sharding=sh, mesh=mesh,
                             overlap=True)
            extra = shard_map(
                lambda t: lax.psum(t, "data"), mesh=mesh,
                in_specs=P("data", "model", None, None),
                out_specs=P(None, "model", None, None))(xx)
        return jnp.sum(y * y) + jnp.sum(extra) * 1e-9

    found = analysis.audit_step_fn(
        jax.value_and_grad(loss_inj, argnums=(0, 1)), (params, x), plan,
        [spec], mesh, overlap=True, hlo=False, grad_wrt_inputs=True)
    assert any(f.rule == "unpriced-collective" and f.severity == "error"
               for f in found), [f"{f.rule}: {f.message}" for f in found]

    # 2) strip the overlap pin (lower serialized, declare overlapped) ->
    #    schedule-pin-missing
    def loss_ser(p, xx):
        with trace_lib.layer_context(spec.name):
            y = L.conv_apply(p, xx, stride=1, sharding=sh, mesh=mesh,
                             overlap=False)
        return jnp.sum(y * y)

    found = analysis.audit_step_fn(
        jax.value_and_grad(loss_ser, argnums=(0, 1)), (params, x), plan,
        [spec], mesh, overlap=True, hlo=False, grad_wrt_inputs=True)
    assert any(f.rule == "schedule-pin-missing" and f.severity == "error"
               for f in found), [f"{f.rule}: {f.message}" for f in found]
    print("audit: negative cases fire the named rules")


GROUPS = {"conv": check_conv, "attention": check_attention,
          "ssm": check_ssm, "models": check_models, "train": check_train,
          "compress": check_compress, "plan": check_plan,
          "cf": check_cf, "spatial2d": check_spatial2d,
          "multiaxis": check_multiaxis, "memfit": check_memfit,
          "overlap": check_overlap, "trace": check_trace,
          "elastic": check_elastic, "audit": check_audit}

if __name__ == "__main__":
    GROUPS[sys.argv[1]]()
    print(f"dist_checks {sys.argv[1]} OK")
