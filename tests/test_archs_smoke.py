"""Per-architecture smoke tests (deliverable f): a REDUCED same-family
config per assigned arch runs one forward/train step on CPU with shape and
finiteness assertions.  The FULL configs are exercised only by the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import synthetic_lm_batch
from repro.models.lm import transformer as T
from repro.models.lm.modules import ShardCtx
from repro.optim.optimizer import adamw


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    B, S = 2, 32
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch(0, B, S, cfg.vocab).items()}
    if cfg.frontend == "vit_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))

    # forward: shape + finite
    logits = T.forward(params, cfg, batch["tokens"],
                       extra_embeds=batch.get("patch_embeds"),
                       frames=batch.get("frames"), remat=False)
    exp_s = S + (cfg.frontend_len if cfg.frontend == "vit_stub" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one full train step: loss finite, params change, no NaNs
    opt = adamw(1e-3)
    ostate = opt.init(params)
    loss, grads = jax.value_and_grad(T.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    new_params, _ = opt.update(grads, ostate, params)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in
               zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert diff > 0
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_decode_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    params = T.init(jax.random.PRNGKey(0), cfg)
    B = 2
    caches = T.init_decode_state(params, cfg, B, 16, dtype=jnp.float32)
    mem = None
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (B, 16, cfg.d_model))
        mem = T.encode(params, cfg, frames, ShardCtx(), remat=False)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        logits, caches = T.decode_step(params, cfg, tok, caches,
                                       jnp.int32(step), memory=mem)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, :, :64], -1).astype(jnp.int32)


def test_full_config_param_counts():
    """Full configs match the published model sizes within 8%."""
    expect = {"gemma2_9b": 9.2e9, "qwen2_5_14b": 14.8e9,
              "qwen1_5_0_5b": 0.46e9, "olmo_1b": 1.2e9,
              "mixtral_8x7b": 46.7e9, "olmoe_1b_7b": 6.9e9,
              "hymba_1_5b": 1.52e9, "pixtral_12b": 12.3e9,
              "mamba2_780m": 0.78e9,
              "seamless_m4t_large_v2": 1.4e9}
    for a, e in expect.items():
        got = registry.get(a).total_params()
        assert abs(got / e - 1) < 0.08, (a, got, e)


def test_prefill_matches_forward():
    cfg = registry.get("qwen1_5_0_5b", smoke=True)
    params = T.init(jax.random.PRNGKey(0), cfg)
    tokens = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % cfg.vocab
    logits = T.forward(params, cfg, tokens, remat=False)
    last, kv, _ = T.prefill(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1:]),
                               rtol=1e-5, atol=1e-5)
    assert len(kv) == len(T.plan(cfg))
