"""Calibration subsystem (core.calibrate) + EmpiricalTable-path tests.

All calibration runs here inject a deterministic fake timer (no kernel is
ever executed, no wall clock is read), so the fitting/serialization logic
is checked exactly and the tests are immune to machine noise.  The
measured-for-real path is exercised by benchmarks/strategy_exec.py and the
CI bench lane.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core import perfmodel as pm
from repro.core.perfmodel import ConvLayer, EmpiricalTable, TPU_V5E
from repro.core.plan import plan_line
from repro.models.cnn import meshnet

MS22 = {"data": 2, "model": 2}

CFG = meshnet.MeshNetConfig("t", input_hw=32, in_channels=4,
                            convs_per_block=1, widths=(8, 16))
SPECS = meshnet.layer_specs(CFG, 4)


def fake_timer(fn, *args):
    """Deterministic stand-in for the trimmed-mean loop: seconds derived
    from the argument sizes only (never calls `fn`)."""
    return 2e-6 + 1e-9 * sum(int(np.prod(a.shape)) for a in args)


# ------------------------------------------------------------ the table --
def test_table_json_roundtrip():
    t = EmpiricalTable({("conv", 4, 8, 32, 32, 16, 3, 1): 1.5e-4,
                        ("pool", 4, 8, 16, 16, 8, 2, 2): 2.0e-5})
    rows = json.loads(json.dumps(t.to_json()))     # through real JSON text
    t2 = EmpiricalTable.from_json(rows)
    assert t2 == t
    layer = ConvLayer("l", n=8, c=8, h=64, w=64, f=16, k=3, s=1)
    assert t2.lookup(layer, 4, 8, 32, 32, 16) == pytest.approx(1.5e-4)
    assert t2.lookup(layer, 9, 9, 9, 9, 9) is None


def test_table_shapes_cover_solver_queries():
    """Every shape layer_cost queries for an executable candidate is a key
    the calibrator measures — the table never misses on the solver's own
    candidate set."""
    from repro.core.plan import executable_candidates
    keys = set(cal.table_shapes(SPECS, MS22))
    probe = EmpiricalTable({k: 1e-4 for k in keys})
    hits = {"n": 0}

    class Counting(EmpiricalTable):
        def lookup(self, layer, n, c, h, w, f):
            t = probe.lookup(layer, n, c, h, w, f)
            assert t is not None, (layer.name, n, c, h, w, f)
            hits["n"] += 1
            return t

    for layer in SPECS:
        for d in executable_candidates(layer, MS22):
            pm.layer_cost(TPU_V5E, layer, d, MS22, Counting())
    assert hits["n"] > 0


# ----------------------------------------------------- calibration runs --
def test_calibration_roundtrip(tmp_path):
    c = cal.calibrate(SPECS, MS22, timer=fake_timer)
    path = str(tmp_path / "BENCH_calibration.json")
    c.save(path)
    c2 = cal.Calibration.load(path)
    assert c2.machine == c.machine
    assert c2.table == c.table
    assert c2.meta == c.meta
    assert len(c.table) > 0
    assert c.machine.peak_flops > 0 and c.machine.mem_bw > 0


def test_calibration_rejects_foreign_json(tmp_path):
    path = str(tmp_path / "not_cal.json")
    with open(path, "w") as f:
        json.dump({"schema": "something-else"}, f)
    with pytest.raises(ValueError, match="schema"):
        cal.Calibration.load(path)


def test_calibration_deterministic_under_seeded_timings():
    """Same specs + same (fake) timings -> bit-identical calibration JSON:
    the pipeline adds no hidden nondeterminism of its own."""
    c1 = cal.calibrate(SPECS, MS22, timer=fake_timer)
    c2 = cal.calibrate(SPECS, MS22, timer=fake_timer)
    assert c1.to_json() == c2.to_json()


def test_load_or_run_is_idempotent(tmp_path):
    path = str(tmp_path / "c.json")
    c1 = cal.load_or_run(path, SPECS, MS22, timer=fake_timer)
    # second call must load, not re-measure: a timer that explodes proves it
    def boom(fn, *a):
        raise AssertionError("re-measured instead of loading")
    c2 = cal.load_or_run(path, SPECS, MS22, timer=boom)
    assert c2.to_json() == c1.to_json()


def test_load_warns_when_calibration_covers_foreign_network(tmp_path,
                                                            capsys):
    """Loading a calibration measured for a different network keeps the
    file (analytic fallback) but warns loudly about the coverage gap."""
    path = str(tmp_path / "c.json")
    c = cal.load_or_run(path, SPECS, MS22, timer=fake_timer)
    assert cal.coverage(c, SPECS, MS22) == pytest.approx(1.0)
    other = meshnet.layer_specs(
        meshnet.MeshNetConfig("o", input_hw=128, in_channels=6,
                              convs_per_block=2, widths=(12, 24)), 8)
    capsys.readouterr()
    c2 = cal.load_or_run(path, other, MS22, timer=fake_timer)
    out = capsys.readouterr().out
    assert "WARNING" in out and "covers only" in out
    assert c2.table == c.table          # loaded, not re-measured


def test_load_or_run_grows_table_for_new_shapes(tmp_path, capsys):
    """grow_table=True: a loaded calibration is extended with the shard
    shapes a new network adds (and saved back), instead of degrading to
    the analytic fallback — the CI bench lane's cross-run cache contract."""
    path = str(tmp_path / "c.json")
    c = cal.load_or_run(path, SPECS, MS22, timer=fake_timer)
    n0 = len(c.table)
    other = meshnet.layer_specs(
        meshnet.MeshNetConfig("o", input_hw=128, in_channels=6,
                              convs_per_block=2, widths=(12, 24)), 8)
    capsys.readouterr()
    c2 = cal.load_or_run(path, other, MS22, timer=fake_timer,
                         grow_table=True)
    out = capsys.readouterr().out
    assert len(c2.table) > n0
    assert "grew" in out and "covers only" not in out
    assert cal.coverage(c2, other, MS22) == pytest.approx(1.0)
    assert cal.coverage(c2, SPECS, MS22) == pytest.approx(1.0)  # kept
    # the grown table was persisted: a reload covers both networks and a
    # further grow call adds nothing
    c3 = cal.load_or_run(path, other, MS22, timer=fake_timer)
    assert c3.table == c2.table
    assert cal.grow(c3, other, MS22, timer=fake_timer) == 0
    # machine constants are untouched by growth (shape-independent fits)
    assert c3.machine == c.machine


def test_calibrate_caps_shape_grid():
    c = cal.calibrate(SPECS, MS22, timer=fake_timer, max_shapes=4)
    assert len(c.table) <= 4
    assert c.meta["shapes"]["dropped"] > 0
    # coverage judges against what a capped run WOULD measure, so a
    # legitimately capped self-calibration is full-coverage (no perpetual
    # "delete the file to re-measure" false alarm)
    assert cal.coverage(c, SPECS, MS22) == pytest.approx(1.0)
    # the capped grid keeps the extremes of the FLOP range
    keys = sorted(c.table.entries,
                  key=lambda k: cal._conv_flops_bytes(k)[0])
    all_keys = sorted(cal.table_shapes(SPECS, MS22),
                      key=lambda k: (cal._conv_flops_bytes(k)[0], k))
    assert keys[0] == all_keys[0] and keys[-1] == all_keys[-1]


# ------------------------------------------------------ solver threading --
def test_solver_with_table_and_analytic_both_executable():
    """plan_line on measured costs and on the analytic model both return
    complete, compiled (executable) plans with cost reports."""
    c = cal.calibrate(SPECS, MS22, timer=fake_timer)
    names = {l.name for l in SPECS}
    for table in (c.table, None):
        plan = plan_line(c.machine, SPECS, MS22, table=table)
        assert set(plan.layers) == names
        assert all(lp.sharding is not None for lp in plan.layers.values())
        assert plan.predicted is not None and plan.predicted["total"] > 0


def test_table_changes_solver_input():
    """The measured table actually feeds the solve: pricing one candidate's
    shapes absurdly high must steer the solver's cost for it."""
    from repro.core.plan import executable_candidates
    layer = ConvLayer("l", n=8, c=8, h=32, w=32, f=8, k=3, s=1)
    slow = EmpiricalTable({k: 10.0 for k in cal.table_shapes([layer], MS22)})
    d = executable_candidates(layer, MS22)[0]
    with_t = pm.layer_cost(TPU_V5E, layer, d, MS22, slow).total
    without = pm.layer_cost(TPU_V5E, layer, d, MS22, None).total
    assert with_t > without * 100


def test_analytic_fallback_on_missing_shapes():
    """Shapes absent from the table fall back to the analytic roofline —
    a partial calibration never changes results for uncovered shapes."""
    layer = ConvLayer("l", n=8, c=8, h=32, w=32, f=8, k=3, s=1)
    empty = EmpiricalTable({})
    other = EmpiricalTable({("conv", 1, 1, 8, 8, 1, 3, 1): 123.0})
    for table in (empty, other):
        got = pm.conv_compute_time(TPU_V5E, layer, 8, 8, 32, 32, 8, table)
        ref = pm.conv_compute_time(TPU_V5E, layer, 8, 8, 32, 32, 8, None)
        assert got == ref
    # and end to end: a table covering nothing solves to the analytic plan
    foreign = EmpiricalTable({("conv", 1, 1, 8, 8, 1, 3, 1): 123.0})
    p_t = plan_line(TPU_V5E, SPECS, MS22, table=foreign)
    p_a = plan_line(TPU_V5E, SPECS, MS22)
    assert all(p_t.layers[n].dist.same_as(p_a.layers[n].dist)
               for n in p_a.layers)
    assert p_t.predicted["total"] == pytest.approx(p_a.predicted["total"])


# ------------------------------------------------------------- fitting --
def test_fit_alpha_beta_recovers_planted_model():
    alpha, beta = 3e-6, 1 / 12e9
    rows = [(1.0, float(n), alpha + beta * n)
            for n in (1 << 10, 1 << 14, 1 << 18, 1 << 22)]
    a, b = cal._fit_alpha_beta(rows, (9e-9, 9e-14))
    assert a == pytest.approx(alpha, rel=1e-6)
    assert b == pytest.approx(beta, rel=1e-6)
    # degenerate systems keep the fallback
    assert cal._fit_alpha_beta([], (1e-6, 1e-10)) == (1e-6, 1e-10)
    assert cal._fit_alpha_beta([(1.0, 5.0, 1.0)], (1e-6, 1e-10)) == \
        (1e-6, 1e-10)


def test_fit_compute_recovers_planted_roofline():
    peak, eff, half = 1e12, 0.5, 2e9
    fls = [1e8, 1e9, 1e10, 1e11]
    samples = [(fl, (fl + half) / (eff * peak) + pm.LAUNCH_OVERHEAD)
               for fl in fls]
    # the planted peak*eff is recoverable up to the achieved-peak anchor
    # (peak is pinned at the best *achieved* rate, which sits below the
    # asymptote, so eff clamps at 1.0 and the product lands a few % off)
    p, e, h = cal._fit_compute(samples, cal.HOST_BASE)
    assert p * e == pytest.approx(peak * eff, rel=0.05)
    assert h == pytest.approx(half, rel=0.05)


def test_comm_sizes_and_representative_subset():
    p2p, coll = cal.comm_sizes(SPECS, MS22)
    assert p2p and coll and all(b > 0 for b in p2p + coll)
    sub = cal._representative(coll, 3)
    assert len(sub) <= 3
    assert sub[0] == min(coll) and sub[-1] == max(coll)
    assert cal._representative([7], 3) == [7]


def test_machine_json_roundtrip():
    m = dataclasses.replace(TPU_V5E, name="x", eff_halfwork=1.5e9)
    m2 = pm.Machine(**json.loads(json.dumps(dataclasses.asdict(m))))
    assert m2 == m


# --------------------------------------------------------------- eta fit --
def test_eta_fit_defaults_without_live_mesh():
    """fit_eta on a plain mesh-shape dict (no live devices) measures
    nothing and keeps the base machine's η — which is how every fake-timer
    calibration in this file stays deterministic — while calibrate() still
    records the (empty) fit in meta for provenance."""
    eta, samples = cal.fit_eta(MS22, timer=fake_timer)
    assert eta == cal.HOST_BASE.overlap_eta == 1.0
    assert samples == []
    c = cal.calibrate(SPECS, MS22, timer=fake_timer)
    assert c.meta["eta_fit"] == {"eta": 1.0, "samples": []}
    assert c.machine.overlap_eta == 1.0


def test_eta_roundtrips_through_json(tmp_path):
    """A non-default η survives save/load bit-exactly (Machine JSON)."""
    c = cal.calibrate(SPECS, MS22, timer=fake_timer)
    c.machine = dataclasses.replace(c.machine, overlap_eta=0.37)
    c.meta["eta_fit"] = {"eta": 0.37, "samples": [
        {"axis": "model", "p": 2, "t_overlap": 1e-3, "t_serial": 1.5e-3,
         "t_compute": 1e-3, "eta": 0.37}]}
    path = str(tmp_path / "c.json")
    c.save(path)
    c2 = cal.Calibration.load(path)
    assert c2.machine.overlap_eta == 0.37
    assert c2.meta["eta_fit"] == c.meta["eta_fit"]
    assert c2.machine == c.machine


def test_eta_backfill_on_pre_eta_file(tmp_path, capsys):
    """A calibration file written before the η fit existed (no meta
    eta_fit, Machine JSON without the field) is backfilled on load and
    persisted — and a fresh file is never re-measured (load_or_run's
    idempotence contract extends to the η fit)."""
    path = str(tmp_path / "c.json")
    c = cal.load_or_run(path, SPECS, MS22, timer=fake_timer)
    with open(path) as f:
        obj = json.load(f)
    del obj["meta"]["eta_fit"]
    del obj["machine"]["overlap_eta"]
    with open(path, "w") as f:
        json.dump(obj, f)
    capsys.readouterr()
    c2 = cal.load_or_run(path, SPECS, MS22, timer=fake_timer)
    assert "backfilled overlap eta" in capsys.readouterr().out
    assert c2.meta["eta_fit"] == {"eta": 1.0, "samples": []}
    assert c2.machine.overlap_eta == 1.0
    assert c2.to_json() == c.to_json()      # backfill restored the file

    def boom(fn, *a):
        raise AssertionError("re-measured instead of loading")
    c3 = cal.load_or_run(path, SPECS, MS22, timer=boom)
    assert c3.to_json() == c2.to_json()


def test_measured_eta_drives_chunk_default():
    """channel_conv's chunked-CF default resolves from the installed
    measurement: off with no measurement, on at η >= the threshold — and
    a fake-timer calibration (no live mesh, no samples) installs nothing."""
    from repro.core import channel_conv as cc
    before = cc.measured_eta()
    try:
        cc.set_measured_eta(None)
        assert cc.default_channel_chunks() == 1
        assert cc.chunks_decision()[1] == "eta unmeasured"
        cal.calibrate(SPECS, MS22, timer=fake_timer)
        assert cc.measured_eta() is None    # empty fit installs nothing
        cc.set_measured_eta(cc.ETA_CHUNK_THRESHOLD)
        assert cc.default_channel_chunks() == 2
        cc.set_measured_eta(0.1)
        assert cc.default_channel_chunks() == 1
    finally:
        cc.set_measured_eta(before)
