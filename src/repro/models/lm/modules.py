"""Transformer/SSM building blocks, sequence-parallel-aware.

Everything except attention and the SSM recurrence is pointwise in the
sequence dimension, so under the paper's spatial (=sequence) decomposition
it runs with zero communication; attention goes through
core.ring_attention (ring / windowed-halo) and the SSM through
core.seq_ssm (boundary-state halo).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.ring_attention import ring_attention
from repro.core.seq_ssm import seq_prefix_state
from repro.models.lm.config import LMConfig
from repro.utils import cdiv, pcast_varying, shard_map


# ---------------------------------------------------------------------------
# context: where/how the model is sharded
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Any = None
    seq_axis: str | None = None          # paper's spatial axis (None = off)
    batch_axes: tuple[str, ...] = ()
    tp_axis: str | None = None           # beyond-paper channel/filter axis
    unroll: bool = False                 # unroll inner comm scans (dry-run
                                         # probes: loop-free HLO accounting)

    @property
    def seq_size(self) -> int:
        if self.mesh is None or self.seq_axis is None:
            return 1
        axes = (self.seq_axis,) if isinstance(self.seq_axis, str) \
            else tuple(self.seq_axis)
        n = 1
        for a in axes:
            n *= dict(self.mesh.shape)[a]
        return n


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: LMConfig, d: int):
    if cfg.norm == "nonparam_ln":        # olmo: no learnable affine
        return jnp.zeros((0,), jnp.float32)
    return jnp.ones((d,), jnp.float32)


def norm_apply(cfg: LMConfig, w, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
        return (y * w).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
    y = (xf - mean) * lax.rsqrt(var + 1e-5)
    if cfg.norm == "layernorm":
        y = y * w
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, S, H, D) with D even; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32)
                    * (math.log(theta) / d))
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, :, None, :]                      # (1, S, 1, D/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]                         # (B, S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: LMConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {"wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * sc,
         "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * sc,
         "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * sc,
         "wo": jax.random.normal(ks[3], (hq * hd, d), dtype)
         * (1.0 / math.sqrt(hq * hd))}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attn_qkv(p, cfg: LMConfig, x, positions, rope_on=True):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, *, cfg: LMConfig, ctx: ShardCtx, positions,
               window: int | None, causal: bool = True,
               kv_override=None, return_kv: bool = False):
    """Self- (or cross-, via kv_override) attention with ring/halo comm."""
    q, k, v = attn_qkv(p, cfg, x, positions)
    if kv_override is not None:
        k, v = kv_override
    scale = cfg.attn_scale or 1.0 / math.sqrt(cfg.head_dim)
    o = ring_attention(q, k, v, mesh=ctx.mesh, seq_axis=ctx.seq_axis,
                       scale=scale, causal=causal, window=window,
                       softcap=cfg.attn_softcap,
                       batch_axes=ctx.batch_axes, unroll=ctx.unroll)
    b, s = x.shape[:2]
    out = o.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: LMConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"wi": jax.random.normal(ks[0], (d, f), dtype) * sc_in,
         "wo": jax.random.normal(ks[2], (f, d), dtype) * sc_out}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = jax.random.normal(ks[1], (d, f), dtype) * sc_in
    return p


def mlp_apply(p, x, cfg: LMConfig):
    h = x @ p["wi"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["wo"]


def moe_init(key, cfg: LMConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {"router": jax.random.normal(ks[0], (d, e), jnp.float32) * sc_in,
            "wi": jax.random.normal(ks[1], (e, d, f), dtype) * sc_in,
            "wg": jax.random.normal(ks[2], (e, d, f), dtype) * sc_in,
            "wo": jax.random.normal(ks[3], (e, f, d), dtype) * sc_out}


MOE_GROUP = 256      # tokens per routing group (GShard "group" dimension)


def moe_apply(p, x, cfg: LMConfig, ctx: ShardCtx):
    """GShard-style capacity-based top-k dispatch via one-hot matmuls
    (TPU-friendly: no scatter).

    Tokens are routed in fixed *groups* of MOE_GROUP consecutive sequence
    positions, so capacity/cumsum/dispatch tensors are (G, gs, e, cap) —
    O(tokens) total — instead of the O(tokens^2/e) global one-hot.  Group
    boundaries align with sequence shards (gs | S_shard), so under the
    paper's spatial decomposition all routing math is shard-local and the
    only cross-device traffic for MoE is the FSDP weight gather (or the
    all-to-all when the strategy engine picks expert parallelism instead).
    The grouping is a pure function of the shape — independent of the mesh —
    so sharded and unsharded execution are numerically identical.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gs = min(s, MOE_GROUP)
    ns = s // gs
    # keep (batch, seq-chunk) as separate dims: dim0 stays sharded over the
    # data axes and dim1 over the model axis, so every routing tensor below
    # shards cleanly (a merged b*s/gs dim defeats SPMD propagation and
    # replicates the dispatch one-hots on every device).
    xt = x.reshape(b, ns, gs, d)
    logits = (xt.astype(jnp.float32) @ p["router"])       # (b, ns, gs, e)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = lax.top_k(probs, k)                       # (b, ns, gs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(cfg.capacity_factor * k * gs / e))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)    # (b,ns,gs,k,e)
    # position of each (token, choice) within its expert's group buffer
    pos = jnp.cumsum(onehot.reshape(b, ns, gs * k, e), 2) \
        .reshape(b, ns, gs, k, e) - 1.0
    pos_sel = jnp.sum(pos * onehot, axis=-1)              # (b, ns, gs, k)
    keep = (pos_sel < cap)
    oh = onehot * keep[..., None]
    pos_c = jax.nn.one_hot(pos_sel, cap, dtype=jnp.float32) \
        * keep[..., None]                                  # (b,ns,gs,k,cap)
    disp = jnp.einsum("bgtke,bgtkc->bgtec", oh, pos_c)    # 0/1
    comb = jnp.einsum("bgtke,bgtk,bgtkc->bgtec", oh, gate, pos_c)

    xe = jnp.einsum("bgtec,bgtd->bgecd", disp.astype(x.dtype), xt)
    if ctx.tp_axis is not None and e % (dict(ctx.mesh.shape)[ctx.tp_axis]) \
            == 0:
        # expert parallelism (paper §III-D filter parallelism): dispatched
        # tokens all-to-all onto the expert shards; expert weights stay
        # sharded on E and are never gathered.
        espec = P(tuple(ctx.batch_axes) or None, None, ctx.tp_axis, None,
                  None)
        xe = lax.with_sharding_constraint(xe, espec)
    h = jnp.einsum("bgecd,edf->bgecf", xe, p["wi"])
    if cfg.mlp in ("swiglu", "geglu"):
        g = jnp.einsum("bgecd,edf->bgecf", xe, p["wg"])
        act = jax.nn.silu if cfg.mlp == "swiglu" else \
            functools.partial(jax.nn.gelu, approximate=True)
        h = act(g) * h
    ye = jnp.einsum("bgecf,efd->bgecd", h, p["wo"])
    if ctx.tp_axis is not None and e % (dict(ctx.mesh.shape)[ctx.tp_axis]) \
            == 0:
        ye = lax.with_sharding_constraint(
            ye, P(tuple(ctx.batch_axes) or None, None, ctx.tp_axis, None,
                  None))
    y = jnp.einsum("bgtec,bgecd->bgtd", comb.astype(x.dtype), ye)
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# SSD (mamba2) — chunked state-space duality
# ---------------------------------------------------------------------------

def ssm_init(key, cfg: LMConfig, dtype):
    d, di, ds, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * ds + h), dtype)
        / math.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype)
        / math.sqrt(cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (di, d), dtype) / math.sqrt(di),
    }


def _match_vma(x, like):
    """Mark x varying over the same manual axes as `like` (shard_map VMA)."""
    typeof = getattr(jax, "typeof", None)   # absent pre-0.6 (no VMA there)
    vma = getattr(typeof(like), "vma", frozenset()) if typeof else frozenset()
    return pcast_varying(x, tuple(vma))


def _ssd_chunked(xdt, la, B, C, chunk: int, h0=None):
    """Exact chunked SSD scan.

    xdt: (b, l, h, p)  dt-scaled inputs;  la: (b, l, h) log-decay;
    B, C: (b, l, n).  h0: optional initial state (b, h, p, n).
    Returns y: (b, l, h, p), h_final: (b, h, p, n).
    """
    b, l, h, p = xdt.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    while l % chunk:            # largest divisor of l not exceeding `chunk`
        chunk -= 1
    nc = cdiv(l, chunk)
    xz = xdt.reshape(b, nc, chunk, h, p)
    laz = la.reshape(b, nc, chunk, h)
    Bz = B.reshape(b, nc, chunk, n)
    Cz = C.reshape(b, nc, chunk, n)

    cum = jnp.cumsum(laz, axis=2)                       # (b,nc,cl,h)
    # intra-chunk: y_i += sum_{j<=i} C_i.B_j exp(cum_i - cum_j) xdt_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,i,j,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the *exponent*, not the result: exp of the (positive) upper
    # triangle overflows and 0*inf => NaN in the backward pass otherwise.
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    G = jnp.einsum("bzin,bzjn->bzij", Cz, Bz)
    y = jnp.einsum("bzij,bzijh,bzjhp->bzihp", G, decay, xz)

    # chunk summaries: state contributed by each chunk (zero inflow)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (b,nc,cl,h)
    S = jnp.einsum("bzjhp,bzjn,bzjh->bzhpn", xz, Bz, dec_end)
    a_tot = jnp.exp(cum[:, :, -1, :])                    # (b,nc,h)

    # inter-chunk recurrence over chunks
    def scan_fn(hprev, inp):
        a_z, S_z = inp
        hnew = hprev * a_z[..., None, None] + S_z
        return hnew, hprev
    h_init = _match_vma(jnp.zeros((b, h, p, n), jnp.float32), xdt) \
        if h0 is None else h0.astype(jnp.float32)
    a_sw = jnp.moveaxis(a_tot, 1, 0)                     # (nc,b,h)
    S_sw = jnp.moveaxis(S, 1, 0).astype(jnp.float32)     # (nc,b,h,p,n)
    h_fin, h_in = lax.scan(scan_fn, h_init, (a_sw, S_sw))
    h_in = jnp.moveaxis(h_in, 0, 1)                      # (b,nc,h,p,n)

    # inflowing-state contribution to each position
    y_inter = jnp.einsum("bzin,bzhpn,bzih->bzihp", Cz,
                         h_in.astype(xdt.dtype),
                         jnp.exp(cum).astype(xdt.dtype))
    y = (y + y_inter).reshape(b, l, h, p)
    return y, h_fin


def _ssd_local(x, p, cfg: LMConfig, *, axis_name, axis_size, conv_tail=None):
    """Shard-local SSD block body (inside shard_map when seq-sharded)."""
    b, l, d = x.shape
    di, ds, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)

    # depthwise causal conv over the sequence; under sequence sharding the
    # (ssm_conv-1)-sample tail of the left neighbor is a literal halo.
    k = cfg.ssm_conv
    if axis_name is not None:
        from repro.core.halo import halo_exchange
        xbc_pad = halo_exchange(xbc, 1, k - 1, 0, axis_name, axis_size)
    else:
        xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    idx = jnp.arange(l)[:, None] + jnp.arange(k)[None, :]
    windows = xbc_pad[:, idx]                            # (b, l, k, conv)
    xbc = jax.nn.silu(jnp.einsum("blkc,kc->blc", windows, p["conv_w"])
                      + p["conv_b"])

    xin, B, C = jnp.split(xbc, [di, di + ds], axis=-1)
    xin = xin.reshape(b, l, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,l,h)
    A = -jnp.exp(p["A_log"])
    la = dt * A                                          # log decay
    xdt = xin * dt[..., None].astype(xin.dtype)

    if axis_name is None:
        y, _ = _ssd_chunked(xdt, la, B, C, cfg.ssm_chunk)
    else:
        # local pass from zero state -> per-shard summary -> boundary halo
        y0, s_loc = _ssd_chunked(xdt, la, B, C, cfg.ssm_chunk)
        cum_all = jnp.cumsum(la, axis=1)                 # (b,l,h)
        a_tot = jnp.exp(cum_all[:, -1])[:, :, None, None]  # (b,h,1,1)
        h_in = seq_prefix_state(a_tot, s_loc, axis_name, axis_size)
        y_in = jnp.einsum("bln,bhpn,blh->blhp", C, h_in.astype(xdt.dtype),
                          jnp.exp(cum_all).astype(xdt.dtype))
        y = y0 + y_in

    y = y + p["D"][None, None, :, None].astype(y.dtype) * xin
    y = y.reshape(b, l, di)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         * p["gate_norm"]).astype(x.dtype)
    return y @ p["out_proj"]


def ssm_apply(p, x, cfg: LMConfig, ctx: ShardCtx):
    if ctx.seq_axis is None or ctx.seq_size == 1:
        return _ssd_local(x, p, cfg, axis_name=None, axis_size=1)
    spec = P(tuple(ctx.batch_axes) or None, ctx.seq_axis, None)
    fn = functools.partial(_ssd_local, cfg=cfg, axis_name=ctx.seq_axis,
                           axis_size=ctx.seq_size)
    pspec = jax.tree.map(lambda _: P(), p)
    return shard_map(lambda x, p: fn(x, p), mesh=ctx.mesh,
                     in_specs=(spec, pspec), out_specs=spec)(x, p)


def ssm_decode_step(p, x, cfg: LMConfig, state, conv_buf):
    """One-token SSD update.  x: (b, 1, d); state: (b, h, p, n);
    conv_buf: (b, k-1, conv_dim) previous inputs."""
    b = x.shape[0]
    di, ds, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
    win = jnp.concatenate([conv_buf, xbc[:, None]], axis=1)  # (b,k,conv)
    new_buf = win[:, 1:]
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"])
                      + p["conv_b"])
    xin, B, C = jnp.split(xbc, [di, di + ds], axis=-1)
    xin = xin.reshape(b, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,h)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"])))                     # (b,h)
    xdt = xin * dt[..., None].astype(xin.dtype)
    state = state * a[..., None, None] \
        + jnp.einsum("bhp,bn->bhpn", xdt, B).astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", state.astype(xin.dtype), C)
    y = y + p["D"][None, :, None].astype(y.dtype) * xin
    y = y.reshape(b, di) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         * p["gate_norm"]).astype(x.dtype)
    return (y @ p["out_proj"])[:, None], state, new_buf
