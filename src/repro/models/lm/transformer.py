"""Generic LM: dense / MoE / SSM / hybrid / enc-dec / VLM-backbone.

Layer stacks are executed as lax.scan over *stacked* parameters, segmented
by block type:

  * uniform stacks (qwen, olmo, mixtral, mamba2, ...) — one scan;
  * period-2 alternation (gemma2 local/global) — one scan whose body holds
    both layer kinds;
  * fixed global islands (hymba layers {0, mid, last}) — scans between
    unrolled singletons.

This keeps the lowered HLO O(1) in depth — required for the 512-device AOT
dry-runs and for sane compile times at production scale.

Sequence parallelism (the paper's spatial decomposition) threads through
ShardCtx into ring attention / SSD state-passing / windowed-halo attention;
everything else is pointwise in S.  Decoding uses the sequence-sharded KV
cache (core.decode_attention).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.decode_attention import cache_append, decode_attention
from repro.models.lm.config import LMConfig
from repro.models.lm import modules as M
from repro.models.lm.modules import ShardCtx

Segment = tuple[tuple[str, ...], int]


def plan(cfg: LMConfig, types: list[str] | None = None) -> list[Segment]:
    types = types if types is not None else cfg.layer_types()
    if len(set(types)) > 1 and len(types) % 2 == 0:
        unit = tuple(types[:2])
        if types == list(unit) * (len(types) // 2):
            return [(unit, len(types) // 2)]
    segs: list[Segment] = []
    for t in types:
        if segs and segs[-1][0] == (t,):
            segs[-1] = ((t,), segs[-1][1] + 1)
        else:
            segs.append(((t,), 1))
    return segs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: LMConfig, btype: str, dtype):
    p: dict[str, Any] = {"ln1": M.norm_init(cfg, cfg.d_model)}
    keys = jax.random.split(key, 6)
    has_attn = btype in ("attn", "swa", "enc", "xattn") \
        or btype.startswith("hybrid")
    if has_attn:
        p["attn"] = M.attn_init(keys[0], cfg, dtype)
    if btype.startswith("hybrid") or btype == "ssm":
        p["ssm"] = M.ssm_init(keys[1], cfg, dtype)
    if btype.startswith("hybrid"):
        p["fuse_attn"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["fuse_ssm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if btype == "xattn":
        p["ln_cross"] = M.norm_init(cfg, cfg.d_model)
        p["cross"] = M.attn_init(keys[2], cfg, dtype)
    if cfg.sandwich_norm:
        p["ln1_post"] = M.norm_init(cfg, cfg.d_model)
    has_mlp = cfg.d_ff > 0 and btype != "ssm"
    if has_mlp:
        p["ln2"] = M.norm_init(cfg, cfg.d_model)
        if cfg.n_experts:
            p["moe"] = M.moe_init(keys[3], cfg, dtype)
        else:
            p["mlp"] = M.mlp_init(keys[3], cfg, dtype)
        if cfg.sandwich_norm:
            p["ln2_post"] = M.norm_init(cfg, cfg.d_model)
    return p


def _segment_init(key, cfg: LMConfig, seg: Segment, dtype):
    unit, count = seg
    keys = jax.random.split(key, count)

    def one(k):
        ks = jax.random.split(k, len(unit))
        return tuple(_block_init(ks[i], cfg, bt, dtype)
                     for i, bt in enumerate(unit))
    return jax.vmap(one)(keys)


def init(key, cfg: LMConfig, dtype=jnp.float32):
    k_emb, k_dec, k_enc, k_fr = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype)
        / math.sqrt(cfg.d_model),
        "final_norm": M.norm_init(cfg, cfg.d_model),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            jax.random.fold_in(k_emb, 1), (cfg.d_model, cfg.vocab), dtype) \
            / math.sqrt(cfg.d_model)
    segs = plan(cfg)
    keys = jax.random.split(k_dec, len(segs))
    for k, seg in zip(keys, segs):
        params["segments"].append(_segment_init(k, cfg, seg, dtype))
    if cfg.is_encdec:
        enc_segs = plan(cfg, ["enc"] * cfg.n_enc_layers)
        ekeys = jax.random.split(k_enc, len(enc_segs))
        params["enc_segments"] = [
            _segment_init(k, cfg, s, dtype) for k, s in zip(ekeys, enc_segs)]
        params["enc_final_norm"] = M.norm_init(cfg, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_apply(p, x, btype: str, cfg: LMConfig, ctx: ShardCtx, positions,
                 memory=None, collect_kv=False):
    h = M.norm_apply(cfg, p["ln1"], x)
    window = cfg.window if btype in ("swa", "hybrid_s") else None
    causal = btype != "enc"
    kv = None
    if btype == "ssm":
        out = M.ssm_apply(p["ssm"], h, cfg, ctx)
    elif btype.startswith("hybrid"):
        a_out, kv = M.attn_apply(p["attn"], h, cfg=cfg, ctx=ctx,
                                 positions=positions, window=window,
                                 causal=True, return_kv=True)
        s_out = M.ssm_apply(p["ssm"], h, cfg, ctx)
        out = 0.5 * (M.norm_apply(cfg, p["fuse_attn"], a_out)
                     + M.norm_apply(cfg, p["fuse_ssm"], s_out))
    else:
        out, kv = M.attn_apply(p["attn"], h, cfg=cfg, ctx=ctx,
                               positions=positions, window=window,
                               causal=causal, return_kv=True)
    if cfg.sandwich_norm:
        out = M.norm_apply(cfg, p["ln1_post"], out)
    x = x + out

    if btype == "xattn":
        hc = M.norm_apply(cfg, p["ln_cross"], x)
        mem_kv = _cross_kv(p["cross"], cfg, memory)
        c_out = M.attn_apply(p["cross"], hc, cfg=cfg, ctx=ctx,
                             positions=positions, window=None, causal=False,
                             kv_override=mem_kv)
        x = x + c_out

    if cfg.d_ff > 0 and btype != "ssm":
        h = M.norm_apply(cfg, p["ln2"], x)
        if cfg.n_experts:
            out = M.moe_apply(p["moe"], h, cfg, ctx)
        else:
            out = M.mlp_apply(p["mlp"], h, cfg)
        if cfg.sandwich_norm:
            out = M.norm_apply(cfg, p["ln2_post"], out)
        x = x + out
    return x, (kv if collect_kv else None)


def _cross_kv(p, cfg: LMConfig, memory):
    """K/V of the encoder memory (no rope on cross-attention)."""
    b, s, _ = memory.shape
    k = (memory @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(cfg.n_kv_heads, cfg.head_dim)
        v = v + p["bv"].reshape(cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _run_segments(segments, seg_params, x, cfg, ctx, positions, memory=None,
                  remat=True, collect_kv=False, unroll=False):
    all_kv = []
    for seg, sp in zip(segments, seg_params):
        unit, count = seg

        def body(xc, pslice):
            kvs = []
            for bt, bp in zip(unit, pslice):
                xc, kv = _block_apply(bp, xc, bt, cfg, ctx, positions,
                                      memory=memory, collect_kv=collect_kv)
                kvs.append(kv)
            return xc, (tuple(kvs) if collect_kv else None)

        fn = jax.checkpoint(body) if remat else body
        x, kv = lax.scan(fn, x, sp, unroll=count if unroll else 1)
        all_kv.append(kv)
    return x, all_kv


# ---------------------------------------------------------------------------
# forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def _embed(params, cfg: LMConfig, tokens, extra_embeds=None):
    x = params["embed"][tokens]
    if cfg.scale_embedding:
        x = x * math.sqrt(cfg.d_model)
    if extra_embeds is not None:       # modality frontend stub: prefix
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg: LMConfig, x):
    emb = params.get("unembed",
                     params["embed"].T if cfg.tie_embeddings else None)
    logits = x @ emb
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def encode(params, cfg: LMConfig, frames, ctx: ShardCtx, remat=True,
           unroll=False):
    """Encoder stack over frontend embeddings (audio stub input)."""
    positions = jnp.arange(frames.shape[1])
    x = frames
    enc_segs = plan(cfg, ["enc"] * cfg.n_enc_layers)
    x, _ = _run_segments(enc_segs, params["enc_segments"], x, cfg, ctx,
                         positions, remat=remat, unroll=unroll)
    return M.norm_apply(cfg, params["enc_final_norm"], x)


def forward(params, cfg: LMConfig, tokens, ctx: ShardCtx = ShardCtx(),
            extra_embeds=None, frames=None, remat=True, collect_kv=False,
            unroll=False):
    """tokens: (B, S_text).  Returns logits (B, S, V) (and caches)."""
    memory = None
    if cfg.is_encdec:
        assert frames is not None, "enc-dec needs encoder frames"
        memory = encode(params, cfg, frames, ctx, remat=remat,
                        unroll=unroll)
    x = _embed(params, cfg, tokens, extra_embeds)
    positions = jnp.arange(x.shape[1])
    x, kv = _run_segments(plan(cfg), params["segments"], x, cfg, ctx,
                          positions, memory=memory, remat=remat,
                          collect_kv=collect_kv, unroll=unroll)
    x = M.norm_apply(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x)
    if collect_kv:
        return logits, kv, memory
    return logits


def loss_fn(params, batch, cfg: LMConfig, ctx: ShardCtx = ShardCtx(),
            remat=True, unroll=False, vocab_parallel=False):
    """Next-token cross entropy.  batch: tokens/labels (+frames/embeds).

    vocab_parallel=True uses the sharded-embedding lookup + streaming CE
    (models/lm/vocab_parallel.py) — no global logits tensor; requires the
    embedding (and unembed) sharded on V over the model axis.
    """
    if vocab_parallel:
        return _loss_vocab_parallel(params, batch, cfg, ctx, remat, unroll)
    logits = forward(params, cfg, batch["tokens"], ctx,
                     extra_embeds=batch.get("patch_embeds"),
                     frames=batch.get("frames"), remat=remat,
                     unroll=unroll)
    labels = batch["labels"]
    # frontend prefix positions carry no label: score the text tail only
    logits = logits[:, -labels.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


def _loss_vocab_parallel(params, batch, cfg: LMConfig, ctx: ShardCtx,
                         remat, unroll):
    from repro.models.lm import vocab_parallel as VP
    memory = None
    if cfg.is_encdec:
        memory = encode(params, cfg, batch["frames"], ctx, remat=remat,
                        unroll=unroll)
    x = VP.embed_lookup(params["embed"], cfg, batch["tokens"], ctx)
    if cfg.scale_embedding:
        x = x * math.sqrt(cfg.d_model)
    extra = batch.get("patch_embeds")
    labels = batch["labels"]
    if extra is not None:
        x = jnp.concatenate([extra.astype(x.dtype), x], axis=1)
        labels = jnp.concatenate(
            [jnp.full(extra.shape[:2], -1, labels.dtype), labels], axis=1)
    positions = jnp.arange(x.shape[1])
    x, _ = _run_segments(plan(cfg), params["segments"], x, cfg, ctx,
                         positions, memory=memory, remat=remat,
                         unroll=unroll)
    x = M.norm_apply(cfg, params["final_norm"], x)
    table = params["unembed"].T if "unembed" in params else params["embed"]
    return VP.xent_loss(table, cfg, x, labels, ctx)


# -------------------------- serving --------------------------------------

def _kv_cache_spec(ctx: ShardCtx):
    return P(tuple(ctx.batch_axes) or None, ctx.seq_axis, None, None)


def prefill(params, cfg: LMConfig, tokens, ctx: ShardCtx = ShardCtx(),
            extra_embeds=None, frames=None, unroll=False):
    """Run the full prompt, returning (last-position logits, kv caches).

    Caches come back stacked per segment: (count, B, S, Hkv, hd) — sharded
    along S over the model axis (the paper's decomposition applied to the
    KV cache)."""
    logits, kv, memory = forward(params, cfg, tokens, ctx,
                                 extra_embeds=extra_embeds, frames=frames,
                                 remat=False, collect_kv=True,
                                 unroll=unroll)
    return logits[:, -1:], kv, memory


def init_decode_state(params, cfg: LMConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Empty caches for decode-from-scratch (or shapes for the dry-run)."""
    state = []
    for unit, count in plan(cfg):
        seg = []
        for bt in unit:
            entry = {}
            if bt in ("attn", "swa", "xattn") or bt.startswith("hybrid"):
                entry["k"] = jnp.zeros(
                    (count, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                    dtype)
                entry["v"] = jnp.zeros_like(entry["k"])
            if bt == "ssm" or bt.startswith("hybrid"):
                entry["ssm"] = jnp.zeros(
                    (count, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), jnp.float32)
                entry["conv"] = jnp.zeros(
                    (count, batch, cfg.ssm_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), dtype)
            seg.append(entry)
        state.append(tuple(seg))
    return state


def decode_step(params, cfg: LMConfig, tokens, caches, length,
                ctx: ShardCtx = ShardCtx(), memory=None, unroll=False):
    """One greedy decode step.  tokens: (B, 1) current token ids;
    caches: from init_decode_state/prefill; length: current filled length.
    Returns (next-token logits, updated caches)."""
    x = _embed(params, cfg, tokens)
    positions = jnp.full((tokens.shape[0], 1), length, jnp.int32)
    scale = cfg.attn_scale or 1.0 / math.sqrt(max(cfg.head_dim, 1))

    new_caches = []
    for (unit, count), sp, cache in zip(plan(cfg), params["segments"],
                                        caches):
        def body(xc, sliced):
            pslice, cslice = sliced
            new_c = []
            for bt, bp, bc in zip(unit, pslice, cslice):
                xc, nc = _decode_block(bp, xc, bt, cfg, ctx, positions,
                                       length, bc, scale, memory)
                new_c.append(nc)
            return xc, tuple(new_c)

        x, upd = lax.scan(body, x, (sp, cache),
                          unroll=count if unroll else 1)
        new_caches.append(upd)

    x = M.norm_apply(cfg, params["final_norm"], x)
    return _logits(params, cfg, x), new_caches


def _decode_block(p, x, btype, cfg: LMConfig, ctx: ShardCtx, positions,
                  length, cache, scale, memory=None):
    h = M.norm_apply(cfg, p["ln1"], x)
    window = cfg.window if btype in ("swa", "hybrid_s") else None
    new_cache = dict(cache)

    def attend(h):
        q, k, v = M.attn_qkv(p["attn"], cfg, h, positions)
        kc, vc = cache_append(cache["k"], cache["v"], k, v, length,
                              mesh=ctx.mesh, seq_axis=ctx.seq_axis,
                              batch_axes=ctx.batch_axes)
        o = decode_attention(q, kc, vc, length + 1, mesh=ctx.mesh,
                             seq_axis=ctx.seq_axis, scale=scale,
                             window=window, softcap=cfg.attn_softcap,
                             batch_axes=ctx.batch_axes)
        b = h.shape[0]
        out = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
        return out, kc, vc

    if btype == "ssm":
        out, st, buf = M.ssm_decode_step(p["ssm"], h, cfg, cache["ssm"],
                                         cache["conv"])
        new_cache.update(ssm=st, conv=buf)
    elif btype.startswith("hybrid"):
        a_out, kc, vc = attend(h)
        s_out, st, buf = M.ssm_decode_step(p["ssm"], h, cfg, cache["ssm"],
                                           cache["conv"])
        out = 0.5 * (M.norm_apply(cfg, p["fuse_attn"], a_out)
                     + M.norm_apply(cfg, p["fuse_ssm"], s_out))
        new_cache.update(k=kc, v=vc, ssm=st, conv=buf)
    else:
        out, kc, vc = attend(h)
        new_cache.update(k=kc, v=vc)
    if cfg.sandwich_norm:
        out = M.norm_apply(cfg, p["ln1_post"], out)
    x = x + out

    if btype == "xattn" and memory is not None:
        hc = M.norm_apply(cfg, p["ln_cross"], x)
        mk, mv = _cross_kv(p["cross"], cfg, memory)
        b = hc.shape[0]
        qc = (hc @ p["cross"]["wq"])
        if cfg.qkv_bias:
            qc = qc + p["cross"]["bq"]
        qc = qc.reshape(b, 1, cfg.n_heads, cfg.head_dim)
        oc = decode_attention(qc, mk, mv, jnp.int32(memory.shape[1]),
                              mesh=ctx.mesh, seq_axis=ctx.seq_axis,
                              scale=scale, batch_axes=ctx.batch_axes)
        x = x + oc.reshape(b, 1, cfg.n_heads * cfg.head_dim) \
            @ p["cross"]["wo"]

    if cfg.d_ff > 0 and btype != "ssm":
        h = M.norm_apply(cfg, p["ln2"], x)
        out = M.moe_apply(p["moe"], h, cfg, ctx) if cfg.n_experts \
            else M.mlp_apply(p["mlp"], h, cfg)
        if cfg.sandwich_norm:
            out = M.norm_apply(cfg, p["ln2_post"], out)
        x = x + out
    return x, new_cache
