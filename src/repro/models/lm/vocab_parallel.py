"""Ring vocab-parallel embedding + cross-entropy (hillclimb optimization —
the paper's §III-D channel/filter parallelism applied to the embedding,
executed as a ring exactly like the spatial halo sweeps).

Baseline lowering materializes the (B, S, V) logits (2.1 GiB/device bf16
for gemma2 train_4k, x2 again in fp32 for the stable CE) and all-gathers
the tied (V, d) embedding for the output matmul.  Here the embedding stays
V-sharded on the model axis and *rotates around the ring*; each sequence
shard streams its softmax statistics (running max / sum-exp / gold score)
over the visiting vocab blocks:

  transient per step:  (B, S_l, V/P) logits chunk — P^2 x smaller than the
                       global logits tensor;
  collective traffic:  one full table rotation (same bytes the baseline's
                       embedding all-gather already paid) — and the logits
                       never exist.

Exactness: equals the dense path up to fp accumulation order (tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.lm.config import LMConfig
from repro.utils import pcast_varying, shard_map


def _ring(x, axis, axis_size):
    return lax.ppermute(
        x, axis, [(i, (i + 1) % axis_size) for i in range(axis_size)])


def _vma(x, like):
    typeof = getattr(jax, "typeof", None)   # absent pre-0.6 (no VMA there)
    vma = getattr(typeof(like), "vma", frozenset()) if typeof else frozenset()
    return pcast_varying(x, tuple(vma))


def _lookup_local(tokens, table, *, axis, axis_size, unroll):
    """tokens: (B, S_l) local block; table: (V/P, d) local vocab rows.
    The table blocks rotate; each step contributes the rows it owns."""
    vshard = table.shape[0]
    idx = lax.axis_index(axis)
    x = _vma(jnp.zeros(tokens.shape + (table.shape[1],), table.dtype),
             tokens)

    def step(carry, t):
        tbl, x = carry
        src = (idx - t) % axis_size
        lo = src * vshard
        local = jnp.clip(tokens - lo, 0, vshard - 1)
        owns = (tokens >= lo) & (tokens < lo + vshard)
        x = x + jnp.where(owns[..., None], tbl[local], 0)
        return (_ring(tbl, axis, axis_size), x), None

    (_, x), _ = lax.scan(jax.checkpoint(step), (table, x),
                         jnp.arange(axis_size),
                         unroll=axis_size if unroll else 1)
    return x


def embed_lookup(table, cfg: LMConfig, tokens, ctx, seq_axis="model"):
    mesh = ctx.mesh
    n = dict(mesh.shape)[seq_axis]
    if table.shape[0] % n:   # pad (rows beyond the real vocab never match)
        table = jnp.pad(table, ((0, n - table.shape[0] % n), (0, 0)))
    fn = functools.partial(_lookup_local, axis=seq_axis, axis_size=n,
                           unroll=ctx.unroll)
    bspec = tuple(ctx.batch_axes) or None
    # ppermute-only body, sharded outputs: gradient-safe without legacy
    # replication tracking (which cannot transpose the ring scan).
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, seq_axis), P(seq_axis, None)),
        out_specs=P(bspec, seq_axis, None),
        legacy_check_rep=False)(tokens, table)


def _logits_chunk(x, tbl, lo, *, scale, softcap, v_real, vshard):
    logits = ((x * scale) @ tbl.T.astype(x.dtype)).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if v_real % vshard:
        pad = lo + jnp.arange(vshard) >= v_real
        logits = jnp.where(pad[None, None], -1e30, logits)
    return logits


def _make_xent_ring(*, axis, axis_size, scale, softcap, unroll, v_real,
                    vshard, batch_axes=()):
    """(B,S_l) per-token CE via a table ring, with a custom VJP whose
    backward *re-rotates* the table instead of saving per-step residuals:
    forward keeps only (m, se, gold) statistics; backward recomputes each
    logits chunk, emits dlogits = softmax - onehot, accumulates dx locally
    and sends each table block's cotangent around the ring so it arrives
    home after the full rotation.  O(B*S + V/P) memory — no logits tensor,
    no stacked residuals (the flash-attention trick applied to the CE)."""

    def ring_stats(x, tbl, lbl, valid):
        idx = lax.axis_index(axis)
        b, sl, _ = x.shape
        m0 = _vma(jnp.full((b, sl), -1e30, jnp.float32), x)
        se0 = _vma(jnp.zeros((b, sl), jnp.float32), x)
        g0 = _vma(jnp.zeros((b, sl), jnp.float32), x)

        def step(carry, t):
            tblc, m, se, gold = carry
            lo = ((idx - t) % axis_size) * vshard
            logits = _logits_chunk(x, tblc, lo, scale=scale,
                                   softcap=softcap, v_real=v_real,
                                   vshard=vshard)
            m_new = jnp.maximum(m, jnp.max(logits, -1))
            corr = jnp.exp(m - m_new)
            se = se * corr + jnp.sum(jnp.exp(logits - m_new[..., None]), -1)
            local = jnp.clip(lbl - lo, 0, vshard - 1)
            owns = (lbl >= lo) & (lbl < lo + vshard)
            g = jnp.take_along_axis(logits, local[..., None], -1)[..., 0]
            gold = gold + jnp.where(owns, g, 0.0)
            return (_ring(tblc, axis, axis_size), m_new, se, gold), None

        (_, m, se, gold), _ = lax.scan(
            step, (tbl, m0, se0, g0), jnp.arange(axis_size),
            unroll=axis_size if unroll else 1)
        return m, se, gold

    @jax.custom_vjp
    def xent_ring(x, tbl, lbl, valid):
        m, se, gold = ring_stats(x, tbl, lbl, valid)
        logz = m + jnp.log(jnp.maximum(se, 1e-30))
        return jnp.where(valid, logz - gold, 0.0)

    def fwd(x, tbl, lbl, valid):
        m, se, gold = ring_stats(x, tbl, lbl, valid)
        logz = m + jnp.log(jnp.maximum(se, 1e-30))
        return (jnp.where(valid, logz - gold, 0.0),
                (x, tbl, lbl, valid, m, se))

    def bwd(res, g):
        x, tbl, lbl, valid, m, se = res
        idx = lax.axis_index(axis)
        gv = (g * valid).astype(jnp.float32)            # (B, S_l)
        dx0 = _vma(jnp.zeros(x.shape, jnp.float32), x)
        dtbl0 = _vma(jnp.zeros(tbl.shape, jnp.float32), x)

        def step(carry, t):
            tblc, dtblc, dx = carry
            lo = ((idx - t) % axis_size) * vshard
            logits = _logits_chunk(x, tblc, lo, scale=scale,
                                   softcap=softcap, v_real=v_real,
                                   vshard=vshard)
            p = jnp.exp(logits - m[..., None]) / \
                jnp.maximum(se, 1e-30)[..., None]
            local = jnp.clip(lbl - lo, 0, vshard - 1)
            owns = (lbl >= lo) & (lbl < lo + vshard)
            onehot = (jax.nn.one_hot(local, vshard, dtype=jnp.float32)
                      * owns[..., None])
            dlogits = gv[..., None] * (p - onehot)      # (B, S_l, V/P)
            if softcap:   # d tanh-cap: (1 - (logits/cap)^2)
                dlogits = dlogits * (1.0 - jnp.square(logits / softcap))
            if v_real % vshard:   # padded rows: kill 0 * inf from the cap
                pad = lo + jnp.arange(vshard) >= v_real
                dlogits = jnp.where(pad[None, None], 0.0, dlogits)
            b, sl, vs = dlogits.shape
            dlf = dlogits.reshape(b * sl, vs)
            dx = dx + scale * (dlf @ tblc.astype(jnp.float32)) \
                .reshape(b, sl, -1)
            # flat 2-D matmul: einsum("bsv,bsd->vd") would materialize a
            # (b, v, d) partial-product tensor (3.4 GiB here)
            dtblc = dtblc + scale * \
                (dlf.T @ x.reshape(b * sl, -1).astype(jnp.float32))
            return (_ring(tblc, axis, axis_size),
                    _ring(dtblc, axis, axis_size), dx), None

        (_, dtbl, dx), _ = lax.scan(
            step, (tbl, dtbl0, dx0), jnp.arange(axis_size),
            unroll=axis_size if unroll else 1)
        # after a full rotation every block's cotangent is back home; the
        # table is replicated over the batch axes, so its cotangent sums
        # across them (the usual replicated-param psum).
        if batch_axes:
            dtbl = lax.psum(dtbl, batch_axes)
        return dx.astype(x.dtype), dtbl.astype(tbl.dtype), None, None

    xent_ring.defvjp(fwd, bwd)
    return xent_ring


def _xent_local(x, labels, table, *, axis, axis_size, all_axes, scale,
                softcap, unroll, v_real):
    """x: (B, S_l, d); labels: (B, S_l) with -1 = unscored; table (V/P, d).
    Rows >= v_real are padding (vocab rounded up to the shard count)."""
    vshard = table.shape[0]
    valid = labels >= 0
    lbl = jnp.where(valid, labels, 0)
    ring = _make_xent_ring(axis=axis, axis_size=axis_size, scale=scale,
                           softcap=softcap, unroll=unroll, v_real=v_real,
                           vshard=vshard,
                           batch_axes=tuple(a for a in all_axes
                                            if a != axis))
    per_tok = ring(x, table, lbl, valid)
    s = lax.psum(jnp.sum(per_tok), all_axes)
    n = lax.psum(jnp.sum(valid.astype(jnp.float32)), all_axes)
    return s, n


def xent_loss(table, cfg: LMConfig, x, labels, ctx, seq_axis="model",
              embed_scale: float = 1.0):
    """Mean next-token CE without materializing global logits.

    x: final hidden states (B, S, d) sequence-sharded; labels (B, S) with
    -1 marking unscored positions; table (V, d) sharded P(seq_axis, None).
    """
    mesh = ctx.mesh
    nsh = dict(mesh.shape)[seq_axis]
    v_real = table.shape[0]
    if v_real % nsh:     # pad the vocab to the shard count (Megatron-style)
        pad = nsh - v_real % nsh
        table = jnp.pad(table, ((0, pad), (0, 0)))
    all_axes = tuple(ctx.batch_axes) + (seq_axis,)
    fn = functools.partial(_xent_local, axis=seq_axis, axis_size=nsh,
                           all_axes=all_axes, scale=embed_scale,
                           softcap=cfg.final_softcap, unroll=ctx.unroll,
                           v_real=v_real)
    bspec = tuple(ctx.batch_axes) or None
    s, n = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, seq_axis, None), P(bspec, seq_axis),
                  P(seq_axis, None)),
        out_specs=(P(), P()))(x, labels, table)
    return s / n
