"""LM architecture configuration.

One `LMConfig` describes every assigned architecture family: dense GQA
transformers, MoE, SSM (mamba2 SSD), hybrid (parallel attn+SSM heads),
VLM/audio backbones (modality frontend stubbed per the assignment) and
encoder-decoder.  `layer_pattern` drives the scan-segmentation of the stack
(period-2 alternation for gemma2, fixed global islands for hymba, uniform
otherwise).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None          # sliding-window width
    layer_pattern: str = "global"      # global | swa | local_global | hymba
    attn_scale: float | None = None    # override 1/sqrt(head_dim)

    # block structure
    mlp: str = "swiglu"                # swiglu | geglu | gelu | none
    norm: str = "rmsnorm"              # rmsnorm | layernorm | nonparam_ln
    sandwich_norm: bool = False        # gemma2 pre+post norms
    scale_embedding: bool = False      # gemma-style sqrt(d) input scaling
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / hymba SSM heads)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # encoder-decoder
    n_enc_layers: int = 0              # >0 => enc-dec (encoder bidirectional)

    # modality frontend stub: input_specs() supplies (B, S_front, d) embeds
    frontend: str | None = None        # vit_stub | audio_stub
    frontend_len: int = 0              # frontend positions per sample

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def layer_types(self) -> list[str]:
        """Per-layer block type, consumed by the scan segmenter."""
        n = self.n_layers
        if self.family == "ssm":
            return ["ssm"] * n
        if self.layer_pattern == "global":
            return ["attn"] * n
        if self.layer_pattern == "swa":
            return ["swa"] * n
        if self.layer_pattern == "local_global":
            # gemma2: alternating local (sliding window) / global
            return ["swa" if i % 2 == 0 else "attn" for i in range(n)]
        if self.layer_pattern == "hymba":
            # hymba: parallel attn+SSM heads everywhere; full attention on
            # first / middle / last layers, SWA elsewhere (arXiv:2411.13676)
            glob = {0, n // 2, n - 1}
            return ["hybrid_g" if i in glob else "hybrid_s" for i in range(n)]
        raise ValueError(self.layer_pattern)

    def params_per_token(self) -> float:
        """Active parameters touched per token (for 6ND MODEL_FLOPS)."""
        d, hq, hkv, hd = self.d_model, self.n_heads, self.n_kv_heads, \
            self.head_dim
        total = 0.0
        for t in self.layer_types():
            if t in ("attn", "swa"):
                attn = d * (hq + 2 * hkv) * hd + hq * hd * d
                total += attn
                total += self._mlp_params()
            elif t == "ssm":
                total += self._ssm_params()
            elif t.startswith("hybrid"):
                attn = d * (hq + 2 * hkv) * hd + hq * hd * d
                total += attn + self._ssm_params() + self._mlp_params()
        if self.is_encdec:   # add encoder + cross-attention
            enc = self.n_enc_layers * (4 * d * hq * hd + self._mlp_params())
            cross = self.n_layers * (4 * d * hq * hd)
            total += enc + cross
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def _mlp_params(self) -> float:
        if self.mlp == "none" or self.d_ff == 0:
            return 0.0
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        per_ff = mult * self.d_model * self.d_ff
        if self.n_experts:           # active experts only
            return self.top_k * per_ff + self.d_model * self.n_experts
        return per_ff

    def total_params(self) -> float:
        """Total (not active) parameters, for memory estimates."""
        act = self.params_per_token()
        if self.n_experts:
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            per_ff = mult * self.d_model * self.d_ff
            act += self.n_layers * (self.n_experts - self.top_k) * per_ff
        return act

    def _ssm_params(self) -> float:
        di, ds, h = self.d_inner, self.ssm_state, self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * ds + h)
        out_proj = di * self.d_model
        return in_proj + out_proj + self.ssm_conv * (di + 2 * ds)
