"""ResNet-50 (He et al.) for ImageNet-1K — the paper's §VI-B2 workload.

Functional implementation on the distribution-aware layers; `apply` executes
a `NetworkPlan` (core.plan): a per-layer distribution for every conv/pool —
keyed by the same names `resnet_graph` exports to the strategy optimizer —
with explicit §III-C reshard points at distribution changes.  Per-layer
entries may be `CFSharding`s (§III-D channel/filter parallelism,
core.channel_conv): the optimizer discovers those for the res4/res5 blocks,
where 7x7 feature maps stop admitting spatial splits but C reaches
1024/2048.  A legacy single `ConvSharding` is accepted too (lowered to a
uniform plan), which runs the whole network under one sample/spatial/hybrid
distribution exactly as before (paper Table III uses 32 samples per 1/2/4
GPUs).

`resnet_graph` exports the branchy layer DAG consumed by the strategy
optimizer's longest-path-first pass (paper §V-C).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import networkx as nx

from repro.core.perfmodel import ConvLayer
from repro.core.spatial_conv import ConvSharding
from repro.models.cnn import layers as L

STAGES = (3, 4, 6, 3)
WIDTHS = (64, 128, 256, 512)
EXPANSION = 4


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet50"
    input_hw: int = 224
    in_channels: int = 3
    n_classes: int = 1000
    stages: tuple = STAGES
    widths: tuple = WIDTHS
    bn_scope: str = "local"


RESNET50 = ResNetConfig()


def _bottleneck_init(key, c_in, width, stride, dtype):
    ks = jax.random.split(key, 4)
    p = {"conv1": L.conv_init(ks[0], 1, c_in, width, dtype),
         "bn1": L.bn_init(width, dtype),
         "conv2": L.conv_init(ks[1], 3, width, width, dtype),
         "bn2": L.bn_init(width, dtype),
         "conv3": L.conv_init(ks[2], 1, width, width * EXPANSION, dtype),
         "bn3": L.bn_init(width * EXPANSION, dtype)}
    if c_in != width * EXPANSION or stride != 1:
        p["proj"] = L.conv_init(ks[3], 1, c_in, width * EXPANSION, dtype)
        p["bn_proj"] = L.bn_init(width * EXPANSION, dtype)
    return p


def init(key, cfg: ResNetConfig = RESNET50, dtype=jnp.float32):
    key, k1, k2 = jax.random.split(key, 3)
    params = {"conv1": L.conv_init(k1, 7, cfg.in_channels, 64, dtype),
              "bn1": L.bn_init(64, dtype),
              "blocks": [],
              "head": None}
    c_in = 64
    for s, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for b in range(n_blocks):
            key, kb = jax.random.split(key)
            stride = 2 if (b == 0 and s > 0) else 1
            params["blocks"].append(
                _bottleneck_init(kb, c_in, width, stride, dtype))
            c_in = width * EXPANSION
    key, kh = jax.random.split(key)
    params["head"] = L.dense_init(kh, c_in, cfg.n_classes, dtype)
    return params


def _bottleneck_apply(p, x, *, pre, stride, plan, mesh, scope, overlap):
    """`pre` is the block's name prefix (e.g. "res3a_branch"): convs are
    named pre+"2a"/"2b"/"2c" and the projection pre+"1", matching
    `resnet_graph`, so the plan addresses every conv individually."""
    def conv(name, pp, z, s):
        z = plan.reshard(z, name, mesh)
        return L.conv_apply(pp, z, stride=s, sharding=plan.sharding(name),
                            mesh=mesh, overlap=overlap)

    def bn(name, pp, z):
        shb = plan.sharding(name).fit(z.shape[1], z.shape[2], 1, 1, mesh)
        return L.bn_apply(pp, z, sharding=shb, mesh=mesh, scope=scope)

    y = conv(pre + "2a", p["conv1"], x, 1)
    y = L.relu(bn(pre + "2a", p["bn1"], y))
    y = conv(pre + "2b", p["conv2"], y, stride)
    y = L.relu(bn(pre + "2b", p["bn2"], y))
    y = conv(pre + "2c", p["conv3"], y, 1)
    y = bn(pre + "2c", p["bn3"], y)
    if "proj" in p:
        x = conv(pre + "1", p["proj"], x, stride)
        x = bn(pre + "1", p["bn_proj"], x)
    return L.relu(x + y)


def apply(params, x, cfg: ResNetConfig = RESNET50, plan=None, mesh=None,
          overlap=True):
    """x: (N, H, W, 3) -> logits (N, n_classes).

    `plan`: a core.plan.NetworkPlan (per-layer distributions + reshard
    points) or a single legacy ConvSharding applied uniformly.
    """
    from repro.core.plan import NetworkPlan
    plan = NetworkPlan.of(plan)
    x = plan.reshard(x, "conv1", mesh)
    x = L.conv_apply(params["conv1"], x, stride=2,
                     sharding=plan.sharding("conv1"), mesh=mesh,
                     overlap=overlap)
    shb = plan.sharding("conv1").fit(x.shape[1], x.shape[2], 1, 1, mesh)
    x = L.relu(L.bn_apply(params["bn1"], x, sharding=shb, mesh=mesh,
                          scope=cfg.bn_scope))
    x = plan.reshard(x, "pool1", mesh)
    x = L.max_pool(x, window=3, stride=2, sharding=plan.sharding("pool1"),
                   mesh=mesh)
    bi = 0
    last = "pool1"
    for s, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            pre = f"res{s+2}{chr(ord('a')+b)}_branch"
            x = _bottleneck_apply(params["blocks"][bi], x, pre=pre,
                                  stride=stride, plan=plan, mesh=mesh,
                                  scope=cfg.bn_scope, overlap=overlap)
            last = pre + "2c"
            bi += 1
    x = L.global_avg_pool(x, sharding=plan.sharding(last).fit(
        x.shape[1], x.shape[2], 1, 1, mesh), mesh=mesh)
    return L.dense_apply(params["head"], x)


def loss_fn(params, batch, cfg: ResNetConfig = RESNET50, plan=None,
            mesh=None, overlap=True):
    logits = apply(params, batch["image"], cfg, plan, mesh, overlap)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["label"][:, None], axis=1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# perf-model / strategy views
# ---------------------------------------------------------------------------

def layer_specs(n: int, cfg: ResNetConfig = RESNET50) -> list[ConvLayer]:
    """Flat (main-path) conv list for the line-network perf model."""
    out = [ConvLayer("conv1", n=n, c=cfg.in_channels, h=cfg.input_hw,
                     w=cfg.input_hw, f=64, k=7, s=2)]
    hw = cfg.input_hw // 4           # conv1 /2, maxpool /2
    out.append(ConvLayer("pool1", n=n, c=64, h=cfg.input_hw // 2,
                         w=cfg.input_hw // 2, f=64, k=3, s=2, kind="pool"))
    c_in = 64
    for s, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            pre = f"res{s+2}{chr(ord('a')+b)}_branch2"
            out.append(ConvLayer(pre + "a", n=n, c=c_in, h=hw, w=hw,
                                 f=width, k=1, s=1))
            out.append(ConvLayer(pre + "b", n=n, c=width, h=hw, w=hw,
                                 f=width, k=3, s=stride))
            hw2 = hw // stride
            out.append(ConvLayer(pre + "c", n=n, c=width, h=hw2, w=hw2,
                                 f=width * EXPANSION, k=1, s=1))
            hw = hw2
            c_in = width * EXPANSION
    return out


def resnet_graph(n: int, cfg: ResNetConfig = RESNET50) -> nx.DiGraph:
    """Branchy DAG (residual shortcuts included) for §V-C longest-path-first."""
    g = nx.DiGraph()
    specs = layer_specs(n, cfg)
    prev = None

    def add(node, layer):
        g.add_node(node, layer=layer)

    add("conv1", specs[0]); add("pool1", specs[1])
    g.add_edge("conv1", "pool1")
    prev = "pool1"
    i = 2
    c_in, hw = 64, cfg.input_hw // 4
    for s, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            names = [specs[i].name, specs[i + 1].name, specs[i + 2].name]
            for j in range(3):
                add(names[j], specs[i + j])
            g.add_edge(prev, names[0])
            g.add_edge(names[0], names[1])
            g.add_edge(names[1], names[2])
            # projection branch exists iff init added one (channel change
            # OR strided block — e.g. equal-width stage transitions)
            if c_in != width * EXPANSION or stride != 1:
                pname = f"res{s+2}{chr(ord('a')+b)}_branch1"
                add(pname, ConvLayer(pname, n=n, c=c_in, h=hw, w=hw,
                                     f=width * EXPANSION, k=1, s=stride))
                g.add_edge(prev, pname)
                g.add_edge(pname, names[2])
            hw //= stride
            c_in = width * EXPANSION
            prev = names[2]
            i += 3
    return g
