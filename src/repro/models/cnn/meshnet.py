"""The paper's mesh-tangling models (§VI): fully-convolutional VGG-style
semantic segmentation on 1024x1024 (1K) / 2048x2048 (2K) 18-channel inputs.

"six blocks of either three (1K) or five (2K) convolution-batchnorm-ReLU
operations, using 3x3 convolutional filters, and a final convolutional layer
for prediction.  Downsampling is performed via stride-2 convolution at the
first convolutional filter of each block."  Channel widths follow the VGGNet
progression the model was adapted from.  The 2K model's activations exceed a
single 16 GB GPU even at batch size 1 — the paper's headline memory argument
for spatial parallelism.

Per the paper's experiments, one ConvSharding is applied to every layer of a
given configuration ("the same data decomposition for every layer"), but
`apply` accepts a `NetworkPlan` (core.plan) — per-layer distributions with
explicit §III-C reshard points, keyed by the `layer_specs` names — for
strategy-optimizer-driven runs, and a legacy per-layer ConvSharding list.
Plan entries may be `CFSharding`s (§III-D): those layers' conv+BN route
through the channel/filter-parallel runtime (core.channel_conv) — the
natural pick for the late blocks, whose 3x3 convs at 32x32-and-below
spatial extents stop admitting spatial splits while C grows into the
hundreds.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.perfmodel import ConvLayer
from repro.core.spatial_conv import ConvSharding
from repro.models.cnn import layers as L

VGG_WIDTHS = (64, 128, 256, 512, 512, 512)


@dataclasses.dataclass(frozen=True)
class MeshNetConfig:
    name: str
    input_hw: int = 1024
    in_channels: int = 18
    convs_per_block: int = 3          # 3 for 1K, 5 for 2K
    widths: tuple = VGG_WIDTHS
    n_classes: int = 1                # per-pixel tangling logit
    bn_scope: str = "local"           # paper §III-B default

    @property
    def out_hw(self) -> int:
        return self.input_hw // (2 ** len(self.widths))


MESH1K = MeshNetConfig("mesh1k", input_hw=1024, convs_per_block=3)
MESH2K = MeshNetConfig("mesh2k", input_hw=2048, convs_per_block=5)


def init(key, cfg: MeshNetConfig, dtype=jnp.float32):
    params = []
    c_in = cfg.in_channels
    for b, width in enumerate(cfg.widths):
        for i in range(cfg.convs_per_block):
            key, k1 = jax.random.split(key)
            params.append({"conv": L.conv_init(k1, 3, c_in, width, dtype),
                           "bn": L.bn_init(width, dtype)})
            c_in = width
    key, k1 = jax.random.split(key)
    params.append({"conv": L.conv_init(k1, 1, c_in, cfg.n_classes, dtype)})
    return params


def layer_names(cfg: MeshNetConfig) -> list[str]:
    """Execution-order layer names, identical to `layer_specs`."""
    return [f"conv{b+1}_{i+1}" for b in range(len(cfg.widths))
            for i in range(cfg.convs_per_block)] + ["pred"]


def layer_fns(cfg: MeshNetConfig, plan=None, mesh=None, overlap=True):
    """Execution-order ``(name, fn)`` pairs with ``fn(layer_params, x) -> y``.

    Each fn runs one layer end to end under ``trace.layer_context(name)``:
    the §III-C reshard into the layer's distribution, the conv (stride-2 at
    each block head), and — for body layers — BN + ReLU.  ``apply`` is the
    composition of these fns, so whole-network execution and the segmented
    profiler (core.trace.trace_plan, which compiles and times each fn in
    isolation) share one definition of "a layer".
    """
    from repro.core import trace as trace_lib
    from repro.core.plan import NetworkPlan
    names = layer_names(cfg)
    if isinstance(plan, (list, tuple)):
        plan = NetworkPlan.from_shardings(names, plan)
    else:
        plan = NetworkPlan.of(plan)

    def body_fn(name, stride):
        def fn(lp, x):
            with trace_lib.layer_context(name):
                sh = plan.sharding(name)
                x = plan.reshard(x, name, mesh)
                x = L.conv_apply(lp["conv"], x, stride=stride,
                                 sharding=sh, mesh=mesh, overlap=overlap)
                shb = sh.fit(x.shape[1], x.shape[2], 1, 1, mesh)
                x = L.bn_apply(lp["bn"], x, sharding=shb, mesh=mesh,
                               scope=cfg.bn_scope)
                return L.relu(x)
        return fn

    def pred_fn(lp, x):
        with trace_lib.layer_context("pred"):
            x = plan.reshard(x, "pred", mesh)
            return L.conv_apply(lp["conv"], x, stride=1,
                                sharding=plan.sharding("pred"), mesh=mesh,
                                overlap=overlap)

    fns = []
    li = 0
    for b in range(len(cfg.widths)):
        for i in range(cfg.convs_per_block):
            fns.append((names[li], body_fn(names[li], 2 if i == 0 else 1)))
            li += 1
    fns.append(("pred", pred_fn))
    return fns


def apply(params, x, cfg: MeshNetConfig, plan=None, mesh=None, overlap=True):
    """x: (N, H, W, 18) -> per-pixel logits (N, H/64, W/64, n_classes).

    `plan`: a core.plan.NetworkPlan, a single legacy ConvSharding (uniform),
    or a legacy per-layer ConvSharding list aligned with `layer_names`.
    """
    for (_, fn), lp in zip(layer_fns(cfg, plan, mesh, overlap), params):
        x = fn(lp, x)
    return x


def loss_fn(params, batch, cfg: MeshNetConfig, plan=None, mesh=None,
            overlap=True):
    """Per-pixel sigmoid BCE (semantic segmentation of tangling cells)."""
    logits = apply(params, batch["image"], cfg, plan, mesh, overlap)
    labels = batch["label"]
    logits = logits.astype(jnp.float32)
    bce = jnp.maximum(logits, 0) - logits * labels \
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.mean(bce)


def layer_specs(cfg: MeshNetConfig, n: int) -> list[ConvLayer]:
    """Perf-model view (paper §V): one ConvLayer per conv."""
    out = []
    c_in, hw = cfg.in_channels, cfg.input_hw
    for b, width in enumerate(cfg.widths):
        for i in range(cfg.convs_per_block):
            stride = 2 if i == 0 else 1
            out.append(ConvLayer(f"conv{b+1}_{i+1}", n=n, c=c_in, h=hw, w=hw,
                                 f=width, k=3, s=stride))
            if stride == 2:
                hw //= 2
            c_in = width
    out.append(ConvLayer("pred", n=n, c=c_in, h=hw, w=hw, f=cfg.n_classes,
                         k=1, s=1))
    return out
