"""Functional CNN layers, distribution-aware (paper §III-B, §III-D).

Every layer is (init, apply) with explicit parameter pytrees.  `apply` takes
the layer's sharding descriptor (the runtime projection of the paper's D):
under a `ConvSharding`, conv and pool route through the halo-exchange
implementations in repro.core.spatial_conv and BN through
repro.core.spatial_norm; under a `CFSharding` (§III-D channel/filter
parallelism), conv and BN route through the row/column-parallel
implementations in repro.core.channel_conv.  Element-wise ops parallelize
trivially under any distribution (paper: "Element-wise operations such as
ReLUs parallelize trivially").
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.core.channel_conv import CFSharding, cf_batch_norm, cf_conv2d
from repro.core.spatial_conv import ConvSharding, spatial_conv2d, spatial_pool
from repro.core.spatial_norm import batch_norm
from repro.utils import shard_map


def conv_init(key, k: int, c_in: int, c_out: int, dtype=jnp.float32):
    fan_in = k * k * c_in
    w = jax.random.normal(key, (k, k, c_in, c_out), dtype) \
        * jnp.asarray(jnp.sqrt(2.0 / fan_in), dtype)
    return {"w": w}


def conv_apply(params, x, *, stride=1, sharding, mesh=None, overlap=True,
               backend="xla"):
    # both descriptor kinds carry the §III-A geometry fit (CFSharding's
    # covers its composed spatial axes; the CF group is validated at plan
    # compile time)
    sharding = sharding.fit(x.shape[1], x.shape[2], params["w"].shape[0],
                            stride, mesh)
    if isinstance(sharding, CFSharding):
        return cf_conv2d(x, params["w"], strides=(stride, stride),
                         sharding=sharding, mesh=mesh, overlap=overlap,
                         backend=backend)
    return spatial_conv2d(x, params["w"], strides=(stride, stride),
                          sharding=sharding, mesh=mesh, overlap=overlap,
                          backend=backend)


def bn_init(c: int, dtype=jnp.float32):
    return {"gamma": jnp.ones((c,), dtype), "beta": jnp.zeros((c,), dtype)}


def bn_state(c: int):
    return {"mean": jnp.zeros((c,), jnp.float32),
            "var": jnp.ones((c,), jnp.float32)}


def bn_apply(params, x, *, sharding, mesh=None, scope: str = "local"):
    if isinstance(sharding, CFSharding):
        return cf_batch_norm(x, params["gamma"], params["beta"],
                             sharding=sharding, mesh=mesh, scope=scope)
    return batch_norm(x, params["gamma"], params["beta"], sharding=sharding,
                      mesh=mesh, scope=scope)


def relu(x):
    return jnp.maximum(x, 0)


def max_pool(x, *, window=3, stride=2, sharding: ConvSharding, mesh=None):
    sharding = sharding.fit(x.shape[1], x.shape[2], window, stride, mesh)
    return spatial_pool(x, window=(window, window), strides=(stride, stride),
                        sharding=sharding, mesh=mesh, kind="max")


def global_avg_pool(x, *, sharding: ConvSharding, mesh=None):
    """Mean over H, W.  Under spatial sharding this is a local mean + psum —
    cheaper than gathering (communication: one scalar per channel)."""
    if not sharding.is_spatial:
        return jnp.mean(x, axis=(1, 2))
    from jax.sharding import PartitionSpec as P
    mesh = mesh or jax.sharding.get_abstract_mesh()
    axes = sharding.spatial_axes   # flattened, incl. product-axis splits
    shape = dict(mesh.shape)
    denom = 1
    for a in axes:
        denom *= shape[a]

    def fn(x):
        return lax.psum(jnp.mean(x, axis=(1, 2)), axes) / denom

    spec = sharding.x_spec()
    out_spec = P(spec[0], None)
    return shard_map(fn, mesh=mesh, in_specs=(spec,),
                     out_specs=out_spec)(x)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    w = jax.random.normal(key, (d_in, d_out), dtype) \
        * jnp.asarray(jnp.sqrt(1.0 / d_in), dtype)
    return {"w": w, "b": jnp.zeros((d_out,), dtype)}


def dense_apply(params, x):
    return x @ params["w"] + params["b"]
