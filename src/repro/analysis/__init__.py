"""Static analysis: prove costed == executed before running a step.

Two passes over a compiled NetworkPlan, both lowering-only:

  * lint   — pure-static consistency of the plan object itself
             (divisibility, load-bearing demotions, reshard coverage,
             memory fit, spec round-trip);
  * audit  — the SPMD collective auditor: walk the traced jaxpr (and
             optionally the lowered StableHLO) of the plan's AOT step and
             join every executed collective against the perf model's
             priced inventory.

Entry points: NetworkPlan.audit(), `train.py --audit`,
`python -m repro.launch.dryrun --audit`, and the CI static lane.
"""
from repro.analysis.lint import (Finding, error_count, format_findings,
                                 lint_plan)
from repro.analysis.collectives import (audit_meshnet, audit_step_fn,
                                        collect_ops, plan_inventory)
from repro.analysis.workloads import WORKLOADS, solve_workload

__all__ = [
    "Finding", "error_count", "format_findings", "lint_plan",
    "audit_meshnet", "audit_step_fn", "collect_ops", "plan_inventory",
    "WORKLOADS", "solve_workload",
]
