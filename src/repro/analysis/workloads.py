"""The bench workload registry — ONE definition of the meshnet
configurations and plan recipes that benchmarks/strategy_exec.py times and
the static lane (launch/dryrun.py --audit) verifies without executing.

Keeping the registry here means "audit every bench workload's solved plan"
cannot drift from "the plans the bench actually runs": both sides import
the same configs and the same solve recipe.
"""
from __future__ import annotations

import dataclasses

from repro.models.cnn import meshnet

CFG128 = meshnet.MeshNetConfig("bench", input_hw=128, in_channels=8,
                               convs_per_block=2, widths=(16, 32, 32),
                               bn_scope="global")
CFG16 = meshnet.MeshNetConfig("bench16", input_hw=16, in_channels=8,
                              convs_per_block=1, widths=(32, 64, 64),
                              bn_scope="global")
CFG2K = meshnet.MeshNetConfig("bench2k", input_hw=64, in_channels=8,
                              convs_per_block=5, widths=(16, 32),
                              bn_scope="global")
CFG16P = meshnet.MeshNetConfig("bench16p", input_hw=32, in_channels=8,
                               convs_per_block=1, widths=(16, 32, 64),
                               bn_scope="global")
CFG2KU = meshnet.MeshNetConfig("bench2ku", input_hw=128, in_channels=8,
                               convs_per_block=2, widths=(16, 32),
                               bn_scope="global")


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    cfg: "meshnet.MeshNetConfig"
    batch: int
    recipe: str          # auto | uniform_h | memfit
    needs_model_axis: bool = False   # skip when the mesh has 1 model way


WORKLOADS = {
    "mesh128": Workload("mesh128", CFG128, 2, "auto"),
    "overlap": Workload("overlap", CFG128, 2, "uniform_h"),
    "mesh16cf": Workload("mesh16cf", CFG16, 2, "auto"),
    "mesh2k_proxy": Workload("mesh2k_proxy", CFG2K, 1, "auto",
                             needs_model_axis=True),
    "mesh16_proxy": Workload("mesh16_proxy", CFG16P, 1, "auto",
                             needs_model_axis=True),
    "mesh2k_unreachable": Workload("mesh2k_unreachable", CFG2KU, 1,
                                   "memfit", needs_model_axis=True),
}


def solve_workload(name: str, machine, mesh, *, table=None,
                   overlap: bool = True, search: str = "greedy"):
    """Solve one bench workload's plan exactly the way the bench does.

    Returns (plan, specs, cfg).  `auto` is the §V-C plan_line solve;
    `uniform_h` is the overlap workload's uniform H-split plan compiled
    through the same cost model; `memfit` derives the synthetic capacity
    limit from the replicated plan's predicted peak (x0.5) and re-solves
    memory-aware — the §VI Table-2 story.  `search` selects the solver's
    search mode (greedy | beam[:N] | hillclimb, strategy.parse_search) for
    the solved recipes; the uniform baseline ignores it.
    """
    from repro.core import plan as plan_lib
    from repro.core.spatial_conv import ConvSharding

    w = WORKLOADS[name]
    specs = meshnet.layer_specs(w.cfg, w.batch)
    names = meshnet.layer_names(w.cfg)
    if w.recipe == "uniform_h":
        sh = ConvSharding(batch_axes=("data",), h_axis="model")
        plan = plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(sh) for n in names},
            specs, mesh, machine=machine, table=table, overlap=overlap)
    elif w.recipe == "memfit":
        rep = plan_lib.compile_plan(
            {n: plan_lib._sharding_to_dist(ConvSharding()) for n in names},
            specs, mesh, machine=machine, table=table, overlap=overlap)
        limit = 0.5 * rep.predicted["memory"]["peak_bytes"]
        plan = plan_lib.plan_line(machine, specs, mesh, table=table,
                                  overlap=overlap, mem_limit=limit,
                                  search=search)
    else:
        plan = plan_lib.plan_line(machine, specs, mesh, table=table,
                                  overlap=overlap, search=search)
    return plan, specs, w.cfg
