"""SPMD collective auditor — prove costed == executed before running a step.

The perf model (core.perfmodel.layer_collectives) declares the priced
inventory: every collective the runtime should issue for a layer under its
distribution, with kind, payload bytes, mesh axes and the cost term that
charges it.  This module walks the *traced* program — the closed jaxpr of
the plan's AOT step, and optionally its lowered StableHLO — inventories
every collective actually issued (attributed to layers via the named-region
op_name metadata, core.trace), and joins the two, flagging:

  unpriced-collective   comm in the program the solver never charged — the
                        prime suspect for the mesh16 model/measured drift;
  phantom-charge        priced comm absent from the program — the solver
                        penalized a plan for messages it never sends;
  payload-mismatch      priced and executed bytes disagree beyond
                        tolerance (>25% error, >5% warning);
  uncharged-collective  comm the model *knowingly* leaves unpriced
                        (charged=False inventory entries, e.g. the CF
                        slice-VJP weight psum) — warning, never error;
  schedule-pin-missing  an interior-split layer without its §IV-A
                        optimization_barrier pin (fwd or bwd);
  halo-after-interior   halo ppermutes issued after the interior conv —
                        the latency-hiding order violated;
  lowering-mismatch /   (hlo pass) layer attribution or per-kind op counts
  hlo-count-mismatch    lost between jaxpr and StableHLO.

Everything here is lowering-only: jax.make_jaxpr / jax.jit(...).lower on
ShapeDtypeStructs.  No timers, no devices doing real work.

Byte convention: an executed collective's payload is the SUM of its input
avals' bytes (a two-operand psum counts both), and inventory entries carry
the TOTAL bytes over their `count` ops — so chunked collectives compare on
totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Mapping, Sequence

import numpy as np

from repro.analysis.lint import Finding
from repro.core import perfmodel as pm
from repro.core import trace as trace_lib

# jaxpr primitive names that move data between devices.  `psum2` is what
# legacy check_rep shard_map emits for lax.psum; `pbroadcast` is its
# no-communication replication bookkeeping twin — deliberately NOT listed.
COLLECTIVE_PRIMS = ("ppermute", "psum", "psum2", "all_gather",
                    "reduce_scatter", "all_to_all")
_KIND_NORM = {"psum2": "psum"}

# relative payload error thresholds for the priced-vs-executed join
PAYLOAD_WARN = 0.05
PAYLOAD_ERROR = 0.25

_CHUNKS_RE = re.compile(r"cf chunks=(\d+)")


@dataclasses.dataclass(frozen=True)
class ExecutedOp:
    """One op of interest found in the traced jaxpr, with attribution."""
    kind: str                 # normalized primitive name (psum2 -> psum)
    layer: str | None         # via the name-stack layer_context prefix
    direction: str            # fwd | bwd ('transpose(' in the name stack)
    region: str | None        # innermost trace.REGIONS name on the path
    path: str                 # full name-stack path (diagnostics)
    bytes: float              # sum over input avals
    axes: frozenset           # mesh axis names the op runs over
    index: int                # pre-order position (schedule checks)


def _axes_of(prim: str, params: Mapping) -> frozenset:
    raw = params.get("axes", params.get("axis_name", ()))
    if isinstance(raw, str):
        raw = (raw,)
    return frozenset(a for a in tuple(raw) if isinstance(a, str))


def _aval_bytes(v) -> float:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0.0
    return float(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def _attr_layer(path: str, names: Sequence[str]) -> str | None:
    for n in sorted(names, key=len, reverse=True):
        if n in path:
            return n
    return None


def _attr_region(path: str) -> str | None:
    best, best_at = None, -1
    for r in trace_lib.REGIONS:
        at = path.rfind(r)
        if at > best_at:
            best, best_at = r, at
    return best


def collect_ops(closed, layer_names: Sequence[str]) -> list[ExecutedOp]:
    """Walk a ClosedJaxpr (pre-order, recursing into every sub-jaxpr in
    eqn params) and inventory the collectives, optimization_barriers and
    conv applications with name-stack attribution."""
    ops: list[ExecutedOp] = []
    counter = [0]

    def walk(jaxpr, prefix):
        for eqn in jaxpr.eqns:
            counter[0] += 1
            nm = eqn.primitive.name
            ns = str(eqn.source_info.name_stack)
            path = (prefix + "/" + ns).strip("/") if ns else prefix
            if nm in COLLECTIVE_PRIMS or nm in (
                    "optimization_barrier", "conv_general_dilated"):
                kind = _KIND_NORM.get(nm, nm)
                ops.append(ExecutedOp(
                    kind=kind,
                    layer=_attr_layer(path, layer_names),
                    direction="bwd" if "transpose(" in path else "fwd",
                    region=_attr_region(path),
                    path=path,
                    bytes=sum(_aval_bytes(v) for v in eqn.invars),
                    axes=_axes_of(nm, eqn.params),
                    index=counter[0]))
            for v in eqn.params.values():
                items = v if isinstance(v, (list, tuple)) else [v]
                for it in items:
                    if hasattr(it, "eqns"):
                        walk(it, path)
                    elif hasattr(it, "jaxpr") and hasattr(it.jaxpr, "eqns"):
                        walk(it.jaxpr, path)

    walk(closed.jaxpr, "")
    return ops


# ---------------------------------------------------------------------------
# the priced-vs-executed join
# ---------------------------------------------------------------------------

def _minor(op: ExecutedOp, cmax: int) -> bool:
    """Small bookkeeping comm the model never prices: BN statistics psums
    and per-channel-vector gradients (gamma/beta) — O(C) words against the
    O(N·H·W·C) collectives the cost terms track."""
    return op.region == "bn_collective" or op.bytes <= 16 * max(cmax, 1)


def join_findings(inventory: Mapping[str, Sequence[pm.CollectiveSpec]],
                  ops: Sequence[ExecutedOp],
                  specs: Sequence[pm.ConvLayer]) -> list[Finding]:
    """Greedy per-entry matching of executed collectives against the
    priced inventory, per (layer, direction, kind): exact axes-set matches
    claim first (largest payload first), then unmatched entries claim any
    remaining same-kind ops — so a tiny priced psum (e.g. the pred layer's
    16-element weight gradient) is matched before leftover classification
    can misroute it."""
    out: list[Finding] = []
    spec_by_name = {s.name: s for s in specs}
    cmax_global = max((max(s.c, s.f) for s in specs), default=1)

    coll = [o for o in ops if o.kind in
            ("ppermute", "psum", "all_gather", "reduce_scatter",
             "all_to_all")]
    by_key: dict[tuple, list[ExecutedOp]] = {}
    for o in coll:
        by_key.setdefault((o.layer, o.direction, o.kind), []).append(o)

    ent_by_key: dict[tuple, list[pm.CollectiveSpec]] = {}
    for layer, entries in inventory.items():
        for e in entries:
            if e.visibility != "jaxpr":
                continue
            ent_by_key.setdefault(
                (layer, e.direction, _KIND_NORM.get(e.kind, e.kind)),
                []).append(e)

    leftovers: list[ExecutedOp] = []
    for key in sorted(set(by_key) | set(ent_by_key),
                      key=lambda k: (str(k[0]), k[1], k[2])):
        layer, direction, kind = key
        remaining = sorted(by_key.get(key, []),
                           key=lambda o: -o.bytes)
        entries = sorted(ent_by_key.get(key, []), key=lambda e: -e.bytes)
        claims: list[list[ExecutedOp]] = [[] for _ in entries]
        for i, e in enumerate(entries):          # pass 1: exact axes match
            want = frozenset(e.axes)
            for o in list(remaining):
                if len(claims[i]) >= e.count:
                    break
                if o.axes == want:
                    claims[i].append(o)
                    remaining.remove(o)
        for i, e in enumerate(entries):          # pass 2: any same-kind op
            while len(claims[i]) < e.count and remaining:
                claims[i].append(remaining.pop(0))
        leftovers.extend(remaining)

        for e, claimed in zip(entries, claims):
            what = (f"{direction} {kind} "
                    f"[{e.region}] over {sorted(e.axes)}")
            if not claimed:
                if e.charged:
                    out.append(Finding(
                        "error", "phantom-charge", layer=layer,
                        message=f"priced {what} "
                                f"({e.bytes:.0f} B, term {e.term}) absent "
                                f"from the traced program — the solver "
                                f"charged comm that never executes",
                        fix="fix layer_collectives' geometry for this "
                            "dist, or the runtime dropped a collective"))
                continue
            cb = sum(o.bytes for o in claimed)
            rel = abs(cb - e.bytes) / max(e.bytes, 1.0)
            if rel > PAYLOAD_WARN:
                sev = "error" if rel > PAYLOAD_ERROR else "warning"
                out.append(Finding(
                    sev, "payload-mismatch", layer=layer,
                    message=f"{what}: priced {e.bytes:.0f} B but the "
                            f"program moves {cb:.0f} B "
                            f"({rel * 100:.0f}% off)",
                    fix="re-derive the shard geometry in "
                        "layer_collectives against the traced shapes"))
            if len(claimed) != e.count:
                out.append(Finding(
                    "warning", "collective-count", layer=layer,
                    message=f"{what}: priced as {e.count} op(s) but the "
                            f"program issues {len(claimed)}",
                    fix="check the chunking/boundary-application count"))
            bad_axes = [o for o in claimed if o.axes != frozenset(e.axes)]
            if bad_axes:
                out.append(Finding(
                    "warning", "collective-axes", layer=layer,
                    message=f"{what}: executed over "
                            f"{sorted(bad_axes[0].axes)} instead",
                    fix="the dist's axis mapping and the runtime's "
                        "shard_map axes disagree"))
            if not e.charged:
                spec = spec_by_name.get(layer)
                cmax = max(spec.c, spec.f) if spec else cmax_global
                out.append(Finding(
                    "info" if e.bytes <= 16 * cmax else "warning",
                    "uncharged-collective", layer=layer,
                    message=f"{what} ({e.bytes:.0f} B) executes but no "
                            f"cost term prices it (known gap — e.g. the "
                            f"CF slice-VJP weight psum, the standing "
                            f"mesh16cf drift suspect)",
                    fix="price it in layer_cost and mark the inventory "
                        "entry charged"))

    minors: dict[tuple, list[ExecutedOp]] = {}
    for o in leftovers:
        spec = spec_by_name.get(o.layer)
        cmax = max(spec.c, spec.f) if spec else cmax_global
        if _minor(o, cmax):
            minors.setdefault((o.layer, o.direction), []).append(o)
        else:
            out.append(Finding(
                "error", "unpriced-collective", layer=o.layer,
                message=f"{o.direction} {o.kind} [{o.region}] over "
                        f"{sorted(o.axes)} moves {o.bytes:.0f} B with no "
                        f"matching priced inventory entry "
                        f"(path {o.path})",
                fix="add it to perfmodel.layer_collectives and charge a "
                    "cost term — unpriced comm is how plans win on paper "
                    "and lose on hardware"))
    for (layer, direction), ms in sorted(
            minors.items(), key=lambda kv: (str(kv[0][0]), kv[0][1])):
        out.append(Finding(
            "info", "uncharged-minor-comm", layer=layer,
            message=f"{len(ms)} {direction} bookkeeping collective(s) "
                    f"({sum(o.bytes for o in ms):.0f} B total: BN stats "
                    f"/ per-channel vectors) — below pricing granularity",
            fix=""))
    return out


# ---------------------------------------------------------------------------
# schedule checks (§IV-A)
# ---------------------------------------------------------------------------

def schedule_findings(ops: Sequence[ExecutedOp], plan,
                      specs: Sequence[pm.ConvLayer],
                      mesh_shape: Mapping[str, int],
                      overlap: bool) -> list[Finding]:
    out: list[Finding] = []
    barriers = [o for o in ops if o.kind == "optimization_barrier"]
    reshard_pins = [o for o in barriers if "reshard" in o.path]
    layer_pins = [o for o in barriers if "reshard" not in o.path]

    for spec in specs:
        lp = plan.layers.get(spec.name)
        dist = lp.dist if lp is not None else None
        if dist is None:
            continue
        expected = pm.interior_split(spec, dist, mesh_shape, overlap)
        mine = [o for o in layer_pins if o.layer == spec.name]
        if expected:
            for direction in ("fwd", "bwd"):
                if not any(o.direction == direction for o in mine):
                    out.append(Finding(
                        "error", "schedule-pin-missing", layer=spec.name,
                        message=f"interior-split layer has no {direction} "
                                f"optimization_barrier pin — XLA is free "
                                f"to reorder the boundary conv before the "
                                f"halo overlap window",
                        fix="HaloSchedule.pin must wrap the interior "
                            "conv (core.spatial_conv)"))
        elif not overlap and mine:
            out.append(Finding(
                "warning", "schedule-pin-unexpected", layer=spec.name,
                message=f"{len(mine)} optimization_barrier pin(s) in a "
                        f"serialized (overlap=False) lowering",
                fix="the serialized path should not pay pin constraints"))

    n_reshards = plan.n_reshards
    if n_reshards and len(reshard_pins) < n_reshards:
        out.append(Finding(
            "warning", "schedule-reshard-pin",
            message=f"{n_reshards} reshard point(s) compiled but only "
                    f"{len(reshard_pins)} reshard double-buffer "
                    f"barrier(s) traced",
            fix="NetworkPlan.reshard pins each redistributed tensor"))

    # halo-before-interior: within each layer's forward, the halo
    # ppermutes must be issued before the interior conv.
    for spec in specs:
        halos = [o.index for o in ops
                 if o.kind == "ppermute" and o.layer == spec.name
                 and o.direction == "fwd" and o.region == "halo_exchange"]
        interior = [o.index for o in ops
                    if o.kind == "conv_general_dilated"
                    and o.layer == spec.name and o.direction == "fwd"
                    and o.region == "conv_interior"]
        if halos and interior and min(halos) > min(interior):
            out.append(Finding(
                "error", "halo-after-interior", layer=spec.name,
                message="halo ppermute issued after the interior conv — "
                        "the §IV-A overlap window is empty",
                fix="HaloSchedule must issue halos before the interior "
                    "conv in program order"))
    return out


# ---------------------------------------------------------------------------
# StableHLO cross-check (attribution survives lowering)
# ---------------------------------------------------------------------------

_HLO_OPS = {"ppermute": "stablehlo.collective_permute",
            "psum": "stablehlo.all_reduce",
            "all_gather": "stablehlo.all_gather",
            "reduce_scatter": "stablehlo.reduce_scatter",
            "optimization_barrier": "stablehlo.optimization_barrier"}


def hlo_findings(asm: str, ops: Sequence[ExecutedOp]) -> list[Finding]:
    out: list[Finding] = []
    layers = sorted({o.layer for o in ops
                     if o.layer and o.kind in ("ppermute", "psum",
                                               "all_gather",
                                               "reduce_scatter")})
    for layer in layers:
        if layer not in asm:
            out.append(Finding(
                "warning", "lowering-mismatch", layer=layer,
                message="layer issues collectives but its name is absent "
                        "from the StableHLO location metadata — profiles "
                        "and the measured-attribution join go blind here",
                fix="layer_context must wrap the whole layer body"))
    for kind, hlo_name in _HLO_OPS.items():
        want = sum(1 for o in ops if o.kind == kind)
        got = asm.count(hlo_name)
        if want != got:
            out.append(Finding(
                "warning", "hlo-count-mismatch",
                message=f"{kind}: {want} in the jaxpr vs {got} "
                        f"{hlo_name} op(s) in the lowered StableHLO",
                fix="lowering fused or duplicated collectives; verify "
                    "against the compiled HLO before trusting payloads"))
    return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _traced_wordsize(args) -> int:
    import jax
    for leaf in jax.tree.leaves(args):
        dt = np.dtype(getattr(leaf, "dtype", np.float32))
        if np.issubdtype(dt, np.floating):
            return dt.itemsize
    return 4


def plan_inventory(plan, specs: Sequence[pm.ConvLayer],
                   mesh_shape: Mapping[str, int], *,
                   machine: pm.Machine | None = None,
                   overlap: bool = True,
                   grad_wrt_inputs: bool = False,
                   wordsize: int = 4) -> dict:
    """The priced inventory for `plan` at the traced wordsize.

    Regenerated (not read from plan.predicted) so the byte comparison is
    dtype-exact: plans are usually costed at the machine's training
    wordsize (TPU_V5E prices bf16) while the audit traces whatever dtype
    the step uses."""
    from repro.core.plan import NetworkPlan, _sharding_to_dist
    plan = NetworkPlan.of(plan)
    m = dataclasses.replace(machine or pm.TPU_V5E, wordsize=wordsize)
    inv = {}
    for i, spec in enumerate(specs):
        lp = plan.layers.get(spec.name)
        if lp is not None and lp.dist is not None:
            dist = lp.dist
        else:
            dist = _sharding_to_dist(plan.sharding(spec.name), spec.name)
        chunks = 1
        if lp is not None:
            mm = _CHUNKS_RE.search(lp.note or "")
            if mm:
                chunks = int(mm.group(1))
        inv[spec.name] = pm.layer_collectives(
            m, spec, dist, mesh_shape, overlap=overlap,
            first=(i == 0 and not grad_wrt_inputs),
            channel_chunks=chunks)
    return inv


def audit_step_fn(fn, args, plan, specs: Sequence[pm.ConvLayer], mesh, *,
                  overlap: bool = True, hlo: bool = True,
                  machine: pm.Machine | None = None,
                  backend: str = "xla",
                  grad_wrt_inputs: bool = False) -> list[Finding]:
    """Audit an arbitrary step function against `plan`'s priced inventory.

    fn:    the step callable (typically jax.value_and_grad of the loss).
    args:  ShapeDtypeStructs (or arrays) matching fn's signature — only
           shapes/dtypes are read; nothing executes.
    specs: the ConvLayers of the plan, in execution order.
    `grad_wrt_inputs=False` declares that the first layer's input gradient
    is dead code (loss wrt params only), so its backward halos are
    expected to be DCE'd.
    """
    import jax
    from repro.core.plan import NetworkPlan
    plan = NetworkPlan.of(plan)
    mesh_shape = dict(mesh.shape)
    with mesh:
        closed = jax.make_jaxpr(fn)(*args)
    ops = collect_ops(closed, [s.name for s in specs])
    inv = plan_inventory(plan, specs, mesh_shape, machine=machine,
                         overlap=overlap, grad_wrt_inputs=grad_wrt_inputs,
                         wordsize=_traced_wordsize(args))
    findings = join_findings(inv, ops, specs)
    findings += schedule_findings(ops, plan, specs, mesh_shape, overlap)
    if hlo:
        with mesh:
            lowered = jax.jit(fn).lower(*args)
        asm = lowered.compiler_ir().operation.get_asm(
            enable_debug_info=True)
        findings += hlo_findings(asm, ops)
    return findings


def audit_meshnet(plan, specs: Sequence[pm.ConvLayer], cfg, mesh, *,
                  machine: pm.Machine | None = None, overlap: bool = True,
                  hlo: bool = False, backend: str = "xla") -> list[Finding]:
    """Audit a meshnet plan's real training step (value_and_grad of
    models.cnn.meshnet.loss_fn) — the convenience entry NetworkPlan.audit
    and the --audit drivers use.  Lowering-only."""
    import jax
    import jax.numpy as jnp

    from repro.models.cnn import meshnet

    n = specs[0].n
    params = jax.eval_shape(
        lambda k: meshnet.init(k, cfg), jax.random.PRNGKey(0))
    batch = {"image": jax.ShapeDtypeStruct(
                 (n, cfg.input_hw, cfg.input_hw, cfg.in_channels),
                 jnp.float32),
             "label": jax.ShapeDtypeStruct(
                 (n, cfg.out_hw, cfg.out_hw, cfg.n_classes), jnp.float32)}

    def loss(p, b):
        return meshnet.loss_fn(p, b, cfg, plan, mesh, overlap)

    return audit_step_fn(
        jax.value_and_grad(loss), (params, batch), plan, specs, mesh,
        overlap=overlap, hlo=hlo, machine=machine, backend=backend,
        grad_wrt_inputs=False)
