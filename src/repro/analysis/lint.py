"""Plan linter — pure-static invariant checks over a compiled NetworkPlan.

No tracing, no lowering, no devices: every rule re-derives an invariant the
plan compiler (core.plan) is supposed to have established and reports where
the plan in hand violates it, as structured `Finding` records in the
PlanError diagnostics style (offending layer named, fix hint attached).

Rule catalog (rule ids are stable — tests and the CI static lane key on
them):

  divisibility             the compiled dist must lower to a runtime
                           sharding that is a fixed point of the §III-A
                           geometry fit (no hidden demotion left to do),
                           divide N, and — for CF layers — divide the
                           channel counts.
  demotion-not-load-bearing  every recorded demotion must be load-bearing:
                           the pre-demotion solved dist must genuinely
                           fail the geometry/channel/executability checks.
  reshard-missing /        reshard_in must hold exactly on layers whose
  reshard-spurious         dist differs from the previous layer's (§III-C
                           coverage, recomputed in execution order).
  reshard-unpriced /       every reshard point must carry a positive
  phantom-shuffle          priced shuffle in predicted['shuffle_per_layer']
                           — and only reshard points may.
  memory-fit               per-layer resident sets and the network peak
                           must fit predicted['memory']['limit_bytes'],
                           findings naming LayerMemory.breakdown().
  spec-roundtrip           to_spec -> dists_from_spec -> compile_plan must
                           reproduce the same shardings and reshard flags
                           (the repro/plan@1 checkpoint contract).
  no-cost-report           (info) the plan was compiled without a machine,
                           so the priced-shuffle and memory rules have
                           nothing to check against.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.core import plan as plan_lib
from repro.core.channel_conv import CFSharding
from repro.core.perfmodel import ConvLayer
from repro.utils import human_bytes

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis result: what rule fired, where, and how to fix.

    severity: 'error' (the costed and executed plans disagree — the audit
    gates fail on these), 'warning' (known model gap or unconfirmed
    convention), 'info' (context, never gating)."""
    severity: str
    rule: str
    message: str
    layer: str | None = None
    fix: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def error_count(findings: Sequence[Finding]) -> int:
    return sum(1 for f in findings if f.severity == "error")


def format_findings(findings: Sequence[Finding]) -> str:
    """Render findings as the fixed-width table --audit modes print."""
    if not findings:
        return "no findings"
    order = {s: i for i, s in enumerate(SEVERITIES)}
    rows = [f"{'severity':8s} {'rule':26s} {'layer':14s} message"]
    for f in sorted(findings, key=lambda f: order.get(f.severity, 9)):
        msg = f.message + (f"  [fix: {f.fix}]" if f.fix else "")
        rows.append(f"{f.severity:8s} {f.rule:26s} {f.layer or '-':14s} "
                    f"{msg}")
    counts = {s: sum(1 for f in findings if f.severity == s)
              for s in SEVERITIES}
    rows.append(" ".join(f"{v} {k}(s)" for k, v in counts.items() if v))
    return "\n".join(rows)


def _load_bearing(solved, spec: ConvLayer,
                  mesh_shape: Mapping[str, int]) -> bool:
    """Would the pre-demotion dist really have failed to execute as-is?"""
    try:
        sh = plan_lib.dist_to_sharding(solved, mesh_shape, layer=spec.name)
    except plan_lib.PlanError:
        return True
    if solved.ways("N", mesh_shape) and spec.n % max(
            solved.ways("N", mesh_shape), 1):
        return True
    gm = plan_lib._geom_mesh(mesh_shape)
    if sh.fit(spec.h, spec.w, spec.k, spec.s, gm) != sh:
        return True
    if isinstance(sh, CFSharding) and not sh.fits_channels(
            spec.c, spec.f, mesh_shape):
        return True
    return False


def lint_plan(plan, specs: Sequence[ConvLayer] | None = None,
              mesh_shape: Mapping[str, int] | None = None) -> list[Finding]:
    """Run every applicable lint rule over `plan`.

    `specs` and `mesh_shape` unlock the geometry-dependent rules
    (divisibility, demotion, spec round-trip); without them only the
    plan-internal rules (reshard coverage, shuffle pricing, memory fit)
    run.  Returns Finding records; error-severity means the plan violates
    an invariant the solver's cost report relies on.
    """
    plan = plan_lib.NetworkPlan.of(plan)
    out: list[Finding] = []
    lps = list(plan.layers.values())
    spec_by_name = {s.name: s for s in (specs or [])}

    # ---- geometry: divisibility / fit fixed point / demotion -------------
    if mesh_shape:
        for lp in lps:
            if lp.dist is None:
                continue
            spec = spec_by_name.get(lp.name)
            try:
                sh = plan_lib.dist_to_sharding(lp.dist, mesh_shape,
                                               layer=lp.name)
            except plan_lib.PlanError as e:
                out.append(Finding(
                    "error", "divisibility", layer=lp.name,
                    message=f"compiled dist does not lower: {e}",
                    fix="recompile the plan; the stored dist predates a "
                        "runtime rule change"))
                continue
            if spec is None:
                continue
            if spec.n % max(lp.dist.ways("N", mesh_shape), 1):
                out.append(Finding(
                    "error", "divisibility", layer=lp.name,
                    message=f"N={spec.n} not divisible by "
                            f"{lp.dist.ways('N', mesh_shape)}-way batch "
                            f"split",
                    fix="demote the batch axes or change the batch size"))
            gm = plan_lib._geom_mesh(mesh_shape)
            fitted = sh.fit(spec.h, spec.w, spec.k, spec.s, gm)
            if fitted != sh:
                out.append(Finding(
                    "error", "divisibility", layer=lp.name,
                    message=f"compiled sharding is not a fixed point of "
                            f"the geometry fit ({spec.h}x{spec.w} vs "
                            f"k={spec.k},s={spec.s}) — the runtime would "
                            f"demote it again, diverging from the cost "
                            f"report",
                    fix="compile through core.plan.compile_plan so the "
                        "demotion is recorded and re-costed"))
            if isinstance(sh, CFSharding) and not sh.fits_channels(
                    spec.c, spec.f, mesh_shape):
                out.append(Finding(
                    "error", "divisibility", layer=lp.name,
                    message=f"channels C={spec.c}->F={spec.f} do not "
                            f"divide the {sh.cf_axis!r} CF axis",
                    fix="compile_plan demotes such layers; this plan "
                        "bypassed it"))
            if lp.solved is not None and not _load_bearing(
                    lp.solved, spec, mesh_shape):
                out.append(Finding(
                    "error", "demotion-not-load-bearing", layer=lp.name,
                    message=f"recorded demotion "
                            f"({lp.note or 'unannotated'}) demoted a dist "
                            f"that executes fine as solved — the plan "
                            f"runs a slower distribution than it charged "
                            f"for",
                    fix="drop the demotion or fix the fit rule that "
                        "triggered it"))

    # ---- reshard coverage (§III-C, recomputed in execution order) --------
    prev = None
    for i, lp in enumerate(lps):
        d = lp.dist
        if d is not None and prev is not None:
            expected = not prev.same_as(d)
            if expected and not lp.reshard_in:
                out.append(Finding(
                    "error", "reshard-missing", layer=lp.name,
                    message="distribution changes at this layer but no "
                            "reshard point is compiled — the runtime "
                            "would feed it a mis-sharded tensor",
                    fix="recompile with core.plan.compile_plan (it "
                        "detects transitions by dist comparison)"))
            if not expected and lp.reshard_in:
                out.append(Finding(
                    "error", "reshard-spurious", layer=lp.name,
                    message="reshard point compiled but the adjacent "
                            "dists are identical — an unpaid shuffle "
                            "the cost report never charged",
                    fix="drop reshard_in; identical dists chain for "
                        "free"))
        if i == 0 and lp.reshard_in:
            out.append(Finding(
                "error", "reshard-spurious", layer=lp.name,
                message="first layer marked reshard_in — the input "
                        "batch is placed by input_spec, never shuffled",
                fix="drop reshard_in on the first layer"))
        prev = d if d is not None else prev

    # ---- priced shuffles -------------------------------------------------
    if plan.predicted is None:
        out.append(Finding(
            "info", "no-cost-report",
            message="plan compiled without a machine: shuffle pricing and "
                    "memory fit have nothing to check against"))
    else:
        shuf = plan.predicted.get("shuffle_per_layer", {})
        for i, lp in enumerate(lps):
            if lp.name not in shuf:
                continue          # cost report covers a sub-path (graphs)
            priced = shuf[lp.name] > 0.0
            if i == 0 and priced:
                out.append(Finding(
                    "error", "phantom-shuffle", layer=lp.name,
                    message="first layer carries a priced shuffle — "
                            "there is no §III-C transition into it",
                    fix="shuffle_per_layer[first] must be 0.0"))
            elif lp.reshard_in and not priced:
                out.append(Finding(
                    "error", "reshard-unpriced", layer=lp.name,
                    message="compiled reshard point carries no priced "
                            "shuffle — the solver compared plans "
                            "without this transition's cost",
                    fix="compile_plan charges shuffle_time to the "
                        "receiving layer; re-attach the cost report"))
            elif i > 0 and not lp.reshard_in and priced:
                out.append(Finding(
                    "error", "phantom-shuffle", layer=lp.name,
                    message=f"priced shuffle "
                            f"({shuf[lp.name] * 1e6:.1f} us) on a layer "
                            f"with no reshard point — comm charged but "
                            f"never executed",
                    fix="recompute shuffle_per_layer from the compiled "
                        "dists"))

        # ---- memory fit vs the recorded limit ----------------------------
        mem = plan.predicted.get("memory")
        if mem is not None and mem.get("limit_bytes"):
            limit = mem["limit_bytes"]
            for name, lm in mem.get("per_layer", {}).items():
                if lm.total > limit:
                    out.append(Finding(
                        "error", "memory-fit", layer=name,
                        message=f"resident set "
                                f"{human_bytes(lm.total)} exceeds the "
                                f"{human_bytes(limit)}/device limit "
                                f"({lm.breakdown()})",
                        fix="re-solve with mem_limit; this plan skipped "
                            "the capacity validation"))
            if mem["peak_bytes"] > limit:
                peak_lm = mem.get("per_layer", {}).get(mem["peak_layer"])
                out.append(Finding(
                    "error", "memory-fit", layer=mem["peak_layer"],
                    message=f"network peak "
                            f"{human_bytes(mem['peak_bytes'])} exceeds "
                            f"the {human_bytes(limit)}/device limit"
                            + (f" ({peak_lm.breakdown()})"
                               if peak_lm is not None else ""),
                    fix="stash accumulation overflows even though each "
                        "layer fits; tighten the per-layer budget "
                        "(plan_line does this automatically)"))

    # ---- repro/plan@1 round trip -----------------------------------------
    if mesh_shape and specs and all(lp.dist is not None for lp in lps) \
            and set(spec_by_name) == set(plan.layers):
        try:
            rec = plan.to_spec(mesh_shape)
            dists = plan_lib.dists_from_spec(rec)
            plan2 = plan_lib.compile_plan(dists, list(specs), mesh_shape)
        except Exception as e:  # noqa: BLE001 — any failure is the finding
            out.append(Finding(
                "error", "spec-roundtrip",
                message=f"to_spec -> compile_plan round trip failed: {e}",
                fix="the stored spec must always re-lower on the mesh it "
                    "was solved for (the checkpoint restore contract)"))
        else:
            for lp in lps:
                lp2 = plan2.layers[lp.name]
                if lp2.sharding != lp.sharding:
                    out.append(Finding(
                        "error", "spec-roundtrip", layer=lp.name,
                        message=f"sharding changed through the "
                                f"repro/plan@1 round trip: "
                                f"{lp.sharding} -> {lp2.sharding}",
                        fix="to_spec must record the post-demotion dist"))
                if lp2.reshard_in != lp.reshard_in:
                    out.append(Finding(
                        "error", "spec-roundtrip", layer=lp.name,
                        message="reshard point "
                                + ("appeared" if lp2.reshard_in
                                   else "vanished")
                                + " through the repro/plan@1 round trip",
                        fix="reshard flags must be a pure function of "
                            "the recorded dists"))
    return out
