"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the *semantic* definitions; the kernels must match them exactly
(up to accumulation order) for every shape/dtype in the test sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, stride: int = 1):
    """VALID conv, NHWC x HWIO -> NHWC (halo/padding handled by caller)."""
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) with Hq % Hkv == 0."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def ssd_chunk_ref(xdt, la, B, C):
    """Single-chunk SSD: y_i = sum_{j<=i} C_i.B_j exp(cum_i-cum_j) xdt_j,
    plus the chunk's outgoing state.  xdt: (b, l, h, p); la: (b, l, h);
    B/C: (b, l, n).  Returns y (b, l, h, p), S (b, h, p, n)."""
    cum = jnp.cumsum(la, axis=1)                        # (b, l, h)
    seg = cum[:, :, None, :] - cum[:, None, :, :]       # (b, i, j, h)
    l = xdt.shape[1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    seg = jnp.where(mask[None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    G = jnp.einsum("bin,bjn->bij", C, B)
    y = jnp.einsum("bij,bijh,bjhp->bihp", G, decay, xdt.astype(jnp.float32))
    dec_end = jnp.exp(cum[:, -1:, :] - cum)
    S = jnp.einsum("bjhp,bjn,bjh->bhpn", xdt.astype(jnp.float32), B,
                   dec_end)
    return y.astype(xdt.dtype), S
