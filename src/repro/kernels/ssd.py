"""Pallas TPU kernel for the SSD (mamba2) intra-chunk pass.

State-space duality makes the within-chunk computation matmul-shaped — the
part worth putting on the MXU:

  y[i]  = sum_{j<=i} (C_i . B_j) * exp(cum_i - cum_j) * xdt_j     (intra)
  S     = sum_j xdt_j (x) B_j * exp(cum_end - cum_j)              (summary)

Grid: (B, n_chunks, heads-blocks).  One chunk x one head-block per program:
  xdt: (1, cl, bh, p)   la: (1, cl, bh)   B/C: (1, cl, n)
  y:   (1, cl, bh, p)   S: (1, bh, p, n)

The inter-chunk recurrence (tiny, sequential) stays in JAX — see
models/lm/modules._ssd_chunked, which this kernel slots into.
fp32 throughout the decay/score math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, s_ref, *, chunk):
    xdt = xdt_ref[0].astype(jnp.float32)              # (cl, bh, p)
    la = la_ref[0].astype(jnp.float32)                # (cl, bh)
    B = b_ref[0].astype(jnp.float32)                  # (cl, n)
    C = c_ref[0].astype(jnp.float32)                  # (cl, n)

    cum = jnp.cumsum(la, axis=0)                      # (cl, bh)
    seg = cum[:, None, :] - cum[None, :, :]           # (i, j, bh)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where((ii >= jj)[..., None], seg, -1e30)
    decay = jnp.exp(seg)                              # (i, j, bh)

    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (i, j)
    M = G[:, :, None] * decay                         # (i, j, bh)
    # y[i,h,p] = sum_j M[i,j,h] xdt[j,h,p]
    y = jnp.einsum("ijh,jhp->ihp", M, xdt,
                   preferred_element_type=jnp.float32)
    y_ref[...] = y[None].astype(y_ref.dtype)

    dec_end = jnp.exp(cum[-1:, :] - cum)              # (cl, bh)
    # S[h,p,n] = sum_j xdt[j,h,p] B[j,n] dec_end[j,h]
    xw = xdt * dec_end[:, :, None]                    # (cl, bh, p)
    s = jnp.einsum("jhp,jn->hpn", xw, B,
                   preferred_element_type=jnp.float32)
    s_ref[...] = s[None]


def ssd_chunk(xdt, la, B, C, *, chunk: int, block_h: int = 0,
              interpret: bool = False):
    """xdt: (b, l, h, p); la: (b, l, h); B/C: (b, l, n) with l % chunk == 0.

    Returns y_intra: (b, l, h, p) and per-chunk summaries S: (b, nc, h, p, n)
    (zero-inflow states; combine across chunks/shards in JAX).
    """
    b, l, h, p = xdt.shape
    n = B.shape[-1]
    assert l % chunk == 0
    nc = l // chunk
    block_h = block_h or h
    while h % block_h:
        block_h -= 1

    xz = xdt.reshape(b * nc, chunk, h, p)
    lz = la.reshape(b * nc, chunk, h)
    Bz = B.reshape(b * nc, chunk, n)
    Cz = C.reshape(b * nc, chunk, n)

    kern = functools.partial(_kernel, chunk=chunk)
    y, s = pl.pallas_call(
        kern,
        grid=(b * nc, h // block_h),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, p),
                         lambda ci, hi: (ci, 0, hi, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda ci, hi: (ci, 0, hi)),
            pl.BlockSpec((1, chunk, n), lambda ci, hi: (ci, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda ci, hi: (ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_h, p),
                         lambda ci, hi: (ci, 0, hi, 0)),
            pl.BlockSpec((1, block_h, p, n), lambda ci, hi: (ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nc, chunk, h, p), xdt.dtype),
            jax.ShapeDtypeStruct((b * nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xz, lz, Bz, Cz)
    return (y.reshape(b, l, h, p), s.reshape(b, nc, h, p, n))
