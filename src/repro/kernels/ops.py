"""jit'd dispatchers for the Pallas kernels.

Backend policy:
  * TPU: run the Pallas kernel compiled (the production path).
  * CPU + REPRO_KERNELS=interpret: run the kernel body in interpret mode
    (exactly what the correctness sweeps in tests/ do).
  * CPU default: the pure-jnp reference — fast enough for CI and the
    numerically identical semantic definition.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import conv2d as _conv
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd as _ssd
from repro.kernels import ref as _ref


def _mode():
    if jax.default_backend() == "tpu":
        return "pallas"
    return os.environ.get("REPRO_KERNELS", "ref")


@functools.partial(jax.jit, static_argnames=("stride", "interior_first"))
def conv2d(x, w, stride: int = 1, interior_first: bool = False):
    # interior_first: the kernel-level §IV-A schedule (boundary row blocks
    # visited last) — a pure reorder the reference path can ignore.
    m = _mode()
    if m == "pallas":
        return _conv.conv2d(x, w, stride=stride,
                            interior_first=interior_first)
    if m == "interpret":
        return _conv.conv2d(x, w, stride=stride, interpret=True,
                            interior_first=interior_first)
    return _ref.conv2d_ref(x, w, stride=stride)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale"))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None):
    m = _mode()
    if m == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)
    if m == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   interpret=True)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    softcap=softcap, scale=scale)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunk(xdt, la, B, C, chunk: int):
    m = _mode()
    if m == "pallas":
        return _ssd.ssd_chunk(xdt, la, B, C, chunk=chunk)
    if m == "interpret":
        return _ssd.ssd_chunk(xdt, la, B, C, chunk=chunk, interpret=True)
    b, l, h, p = xdt.shape
    nc = l // chunk
    ys, ss = [], []
    for i in range(nc):
        sl = slice(i * chunk, (i + 1) * chunk)
        y, s = _ref.ssd_chunk_ref(xdt[:, sl], la[:, sl], B[:, sl], C[:, sl])
        ys.append(y)
        ss.append(s)
    return jnp.concatenate(ys, axis=1), jnp.stack(ss, axis=1)
