"""Pallas TPU conv2d — implicit GEMM over the MXU.

The paper's compute hot spot is the local convolution each shard runs after
its halo exchange (§IV: cuDNN there).  The TPU-native formulation is an
implicit GEMM: for each of the K*K filter taps, a (rows x W_out, C) @ (C, F)
matmul on the MXU, accumulated in fp32 and written once.  No im2col buffer
is materialized at element granularity; the input is re-tiled into
*overlapping row blocks* (overlap = K - stride rows, a ~(1 + K/s/block_h)
duplication) so every VMEM block is perfectly Blocked-indexable.

Grid: (N, H_out/block_h, F/block_f).  VMEM blocks:
  x: (1, 1, block_h*stride + K - stride, W, C)   rows feeding this tile
  w: (K, K, C, block_f)
  y: (1, block_h, W_out, block_f)

block_f is MXU-lane-aligned (128 when F allows); block_h sizes the VMEM
working set:  in_rows*W*C*2B  +  K*K*C*block_f*2B  +  block_h*W_out*block_f*4B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, y_ref, *, kh, kw, stride, block_h, w_out):
    x = x_ref[0, 0]                                  # (in_rows, W, C)
    w = w_ref[...]                                   # (kh, kw, C, bf)
    acc = jnp.zeros(y_ref.shape[1:], jnp.float32)    # (bh, w_out, bf)
    for i in range(kh):
        for j in range(kw):
            xs = x[i:i + block_h * stride:stride,
                   j:j + w_out * stride:stride, :]   # (bh, w_out, C)
            acc += jax.lax.dot_general(
                xs, w[i, j],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    y_ref[...] = acc[None].astype(y_ref.dtype)


def conv2d(x, w, *, stride: int = 1, block_h: int = 8, block_f: int = 128,
           interpret: bool = False, interior_first: bool = False):
    """VALID conv, NHWC x HWIO -> NHWC (same dtype as x).

    Halo/padding is the caller's job (core.spatial_conv supplies the halo
    rows), mirroring the paper's split between communication and the local
    cuDNN call.

    interior_first: visit the interior row blocks before the two boundary
    blocks — the §IV-A interior/boundary schedule inside the kernel.  The
    boundary blocks are the only ones whose input rows include the halo,
    so an in-flight halo transfer gets the whole interior pass to land
    before its rows are read.  Pure grid reorder: every block is computed
    exactly once, numerics unchanged.
    """
    n, h, wd, c = x.shape
    kh, kw, _, f = w.shape
    h_out = (h - kh) // stride + 1
    w_out = (wd - kw) // stride + 1
    block_h = min(block_h, h_out)
    while h_out % block_h:
        block_h -= 1
    block_f = min(block_f, f)
    while f % block_f:
        block_f -= 1
    in_rows = block_h * stride + (kh - stride)
    nh = h_out // block_h

    # overlapping row blocks: (n, nh, in_rows, W, C)
    xb = jnp.stack([
        jax.lax.slice_in_dim(x, b * block_h * stride,
                             b * block_h * stride + in_rows, axis=1)
        for b in range(nh)], axis=1)

    if interior_first and nh > 2:
        # grid step -> row block: interior blocks first, boundaries last.
        order = jnp.asarray(tuple(range(1, nh - 1)) + (0, nh - 1), jnp.int32)
        hmap = lambda hi: order[hi]                  # noqa: E731
    else:
        hmap = lambda hi: hi                         # noqa: E731

    kern = functools.partial(_kernel, kh=kh, kw=kw, stride=stride,
                             block_h=block_h, w_out=w_out)
    return pl.pallas_call(
        kern,
        grid=(n, nh, f // block_f),
        in_specs=[
            pl.BlockSpec((1, 1, in_rows, wd, c),
                         lambda ni, hi, fi: (ni, hmap(hi), 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, block_f),
                         lambda ni, hi, fi: (0, 0, 0, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_h, w_out, block_f),
                               lambda ni, hi, fi: (ni, hmap(hi), 0, fi)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, f), x.dtype),
        interpret=interpret,
    )(xb, w)
