"""Pallas TPU flash attention (blocked online softmax).

The local-shard attention inside ring attention / prefill is the
transformer hot spot.  Classic streaming formulation:

  grid = (B, Hq, Sq/block_q, Sk/block_k)   -- last dim innermost
  VMEM scratch (m, l, acc) persists across the Sk sweep; the output tile is
  written once on the final k-block.

Supports GQA (kv-head = q-head // group via the k/v index_map), causal and
sliding-window masks, and gemma2-style logit softcapping.  Inputs are taken
(B, H, S, D) — the wrapper transposes from the model's (B, S, H, D).

Block sizes default to MXU/VPU-aligned (block_q=block_k=128, D untiled).
fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, causal, window, softcap, block_q, block_k, n_k):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0]                                   # (bq, d)
    k = k_ref[0, 0]                                   # (bk, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_prev * corr + jnp.sum(p, axis=1)
    m_sc[...] = m_new
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[...] = (acc_sc[...] / l[:, None])[None, None] \
            .astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128,
                    interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    while sq % block_q:
        block_q -= 1
    block_k = min(block_k, sk)
    while sk % block_k:
        block_k -= 1
    n_k = sk // block_k

    qt = q.transpose(0, 2, 1, 3)                      # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             block_q=block_q, block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=(b, hq, sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
