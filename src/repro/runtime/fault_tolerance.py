"""Fault-tolerant training driver + straggler monitoring + elastic restart.

On a 1000+-node fleet the failure model is: any step may raise (XLA error,
host OOM, preempted worker surfacing as a collective timeout).  The driver's
contract:

  * checkpoint every `ckpt_every` steps (async, atomic — see
    repro.checkpoint);
  * on failure: roll back to the latest committed checkpoint, rebuild the
    step function (fresh compilation), continue; give up after
    `max_failures` *consecutive* failures;
  * deterministic data: batches are derived from the step index, so a
    restart replays the exact stream (no sample skips/duplicates);
  * elastic restart: because checkpoints are mesh-independent, the restore
    path accepts a *different* mesh factorization than the failed run —
    `launch.train` re-calls make_mesh with whatever devices remain.

StragglerMonitor implements the detection half of straggler mitigation: an
online median/MAD filter over step times; slow steps beyond `k` MADs are
flagged and counted.  On a real cluster the action hook would evict/replace
the slow host (the SPMD program itself cannot out-run its slowest member);
in-process we expose the hook + stats, and the *prevention* levers live in
the step itself (static shapes everywhere -> no recompile jitter; async
checkpointing -> no I/O stalls on the critical path).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import numpy as np

log = logging.getLogger("repro.runtime")


class StragglerMonitor:
    def __init__(self, k: float = 5.0, warmup: int = 3,
                 action: Callable[[int, float], None] | None = None):
        self.k = k
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        hist = np.asarray(self.times[:-1])
        med = np.median(hist)
        mad = np.median(np.abs(hist - med)) + 1e-9
        if dt > med + self.k * mad and dt > 1.5 * med:
            self.flagged.append((step, dt))
            log.warning("straggler step %d: %.3fs (median %.3fs)",
                        step, dt, med)
            if self.action:
                self.action(step, dt)
            return True
        return False

    @property
    def stats(self) -> dict:
        t = np.asarray(self.times) if self.times else np.zeros(1)
        return {"median": float(np.median(t)), "p95": float(np.percentile(t, 95)),
                "flagged": len(self.flagged)}


@dataclasses.dataclass
class ResilientLoop:
    """Runs `run_step(state, step) -> state, metrics` with checkpoint/restart.

    `state` is an arbitrary pytree (params, opt state, ef state, ...).
    `make_step` rebuilds the compiled step fn after a failure (it may also
    re-make the mesh — elastic restart).
    """
    ckpt: Any                      # CheckpointManager
    make_step: Callable[[], Callable]
    ckpt_every: int = 50
    max_failures: int = 3

    def run(self, state, start_step: int, num_steps: int,
            monitor: StragglerMonitor | None = None,
            inject_failure: Callable[[int], None] | None = None):
        step_fn = self.make_step()
        failures = 0
        step = start_step
        metrics = None
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                if inject_failure:
                    inject_failure(step)           # test hook
                state, metrics = step_fn(state, step)
                dt = time.perf_counter() - t0
                if monitor:
                    monitor.record(step, dt)
                failures = 0
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, extra={"step": step})
            except KeyboardInterrupt:
                raise
            except Exception as e:     # noqa: BLE001 — any step fault
                failures += 1
                log.error("step %d failed (%s); failure %d/%d",
                          step, type(e).__name__, failures,
                          self.max_failures)
                if failures > self.max_failures:
                    raise
                self.ckpt.wait()
                restored, manifest = self.ckpt.restore(state)
                if restored is not None:
                    state = restored
                    step = manifest["extra"]["step"]
                    log.info("rolled back to step %d", step)
                else:
                    step = start_step
                step_fn = self.make_step()          # fresh compile / remesh
        self.ckpt.wait()
        return state, step, metrics
