"""Fault-tolerant training driver + straggler monitoring + elastic restart.

On a 1000+-node fleet the failure model is: any step may raise (XLA error,
host OOM, preempted worker surfacing as a collective timeout), and a node
may *leave* — the device set shrinks.  The driver's contract:

  * checkpoint every `ckpt_every` steps (async, atomic — see
    repro.checkpoint), recording the solved plan spec in the manifest;
  * on a step fault: roll back to the latest committed checkpoint, rebuild
    the step function (fresh compilation), continue; give up after
    `max_failures` *consecutive* failures;
  * on device loss (`DeviceLoss`, carrying the surviving devices): hand
    the survivors to the `remesh` callback, which rebuilds the mesh from
    them, re-solves the plan on the shrunk mesh under the same mem_limit
    (launch.train --elastic), and returns a fresh step factory plus a
    state template sharded under the new mesh — the checkpoint's global
    arrays then reshard-on-restore into it;
  * deterministic data: batches are derived from the step index, so a
    restart replays the exact stream (no sample skips/duplicates);
  * observability: with a `metrics` MetricsLogger every fault, rollback,
    remesh and flagged straggler emits a ``repro/metrics@1`` event record.

StragglerMonitor implements the detection half of straggler mitigation: an
online median/MAD filter over step times; slow steps beyond `k` MADs are
flagged and counted.  On a real cluster the action hook would evict/replace
the slow host (the SPMD program itself cannot out-run its slowest member);
in-process we expose the hook + stats, and the *prevention* levers live in
the step itself (static shapes everywhere -> no recompile jitter; async
checkpointing -> no I/O stalls on the critical path).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Sequence

import numpy as np

log = logging.getLogger("repro.runtime")


class DeviceLoss(RuntimeError):
    """A step fault caused by devices leaving the fleet.

    Carries the devices that survive; a `ResilientLoop` with a `remesh`
    callback recovers elastically, anything else treats it as fatal (a
    same-mesh retry cannot succeed without the lost devices).
    """

    def __init__(self, survivors: Sequence, message: str | None = None):
        self.survivors = list(survivors)
        super().__init__(message or
                         f"device loss: {len(self.survivors)} survivors")


class StragglerMonitor:
    def __init__(self, k: float = 5.0, warmup: int = 3,
                 action: Callable[[int, float], None] | None = None):
        self.k = k
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        hist = np.asarray(self.times[:-1])
        med = np.median(hist)
        mad = np.median(np.abs(hist - med)) + 1e-9
        if dt > med + self.k * mad and dt > 1.5 * med:
            self.flagged.append((step, dt))
            log.warning("straggler step %d: %.3fs (median %.3fs)",
                        step, dt, med)
            if self.action:
                self.action(step, dt)
            return True
        return False

    @property
    def stats(self) -> dict:
        t = np.asarray(self.times) if self.times else np.zeros(1)
        return {"median": float(np.median(t)), "p95": float(np.percentile(t, 95)),
                "flagged": len(self.flagged)}


@dataclasses.dataclass
class ResilientLoop:
    """Runs `run_step(state, step) -> state, metrics` with checkpoint/restart.

    `state` is an arbitrary pytree (params, opt state, ef state, ...).
    `make_step` rebuilds the compiled step fn after a failure.
    `remesh` (optional) handles `DeviceLoss`: survivors ->
    (new make_step factory, state template sharded under the new mesh);
    the loop then reshards-on-restore the last checkpoint into the
    template and replays from its step.  Without `remesh`, DeviceLoss is
    fatal — retrying the same mesh without the lost devices cannot work.
    `plan_spec` (dict or zero-arg callable returning one) is recorded in
    every checkpoint manifest; `metrics` (train.metrics.MetricsLogger)
    streams fault/rollback/remesh/straggler events as JSONL records.
    """
    ckpt: Any                      # CheckpointManager
    make_step: Callable[[], Callable]
    ckpt_every: int = 50
    max_failures: int = 3
    remesh: Callable[[Sequence], tuple[Callable, Any]] | None = None
    metrics: Any = None            # MetricsLogger | None
    plan_spec: Any = None          # dict | Callable[[], dict] | None

    def _plan(self) -> dict | None:
        return self.plan_spec() if callable(self.plan_spec) \
            else self.plan_spec

    def _event(self, kind: str, **fields):
        if self.metrics is not None:
            self.metrics.log_event(kind, **fields)

    def _rollback(self, state_like, start_step: int):
        """Restore the latest committed checkpoint into `state_like`'s
        structure and shardings (reshard-on-restore); fall back to the
        template itself at `start_step` when nothing is committed yet."""
        restored, manifest = self.ckpt.restore(state_like)
        if restored is not None:
            step = manifest["extra"]["step"]
            log.info("rolled back to step %d", step)
            self._event("rollback", step=step)
            return restored, step
        self._event("rollback", step=start_step, note="no checkpoint")
        return state_like, start_step

    def run(self, state, start_step: int, num_steps: int,
            monitor: StragglerMonitor | None = None,
            inject_failure: Callable[[int], None] | None = None):
        step_fn = self.make_step()
        failures = 0
        step = start_step
        metrics = None
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                if inject_failure:
                    inject_failure(step)           # test hook
                state, metrics = step_fn(state, step)
                dt = time.perf_counter() - t0
                if monitor and monitor.record(step, dt):
                    self._event("straggler", step=step, dt_s=dt,
                                **monitor.stats)
                failures = 0
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, extra={"step": step},
                                   plan=self._plan())
            except KeyboardInterrupt:
                raise
            except DeviceLoss as e:
                failures += 1
                log.error("step %d lost devices (%d survive); "
                          "failure %d/%d", step, len(e.survivors),
                          failures, self.max_failures)
                self._event("fault", step=step, error="DeviceLoss",
                            survivors=len(e.survivors), failures=failures)
                if failures > self.max_failures or self.remesh is None:
                    raise
                self.ckpt.wait()
                # elastic restart: new mesh + re-solved plan from the
                # survivors, then reshard-on-restore into its template
                self.make_step, state_like = self.remesh(e.survivors)
                self._event("remesh", step=step,
                            n_devices=len(e.survivors))
                state, step = self._rollback(state_like, start_step)
                step_fn = self.make_step()
            except Exception as e:     # noqa: BLE001 — any step fault
                failures += 1
                log.error("step %d failed (%s); failure %d/%d",
                          step, type(e).__name__, failures,
                          self.max_failures)
                self._event("fault", step=step, error=type(e).__name__,
                            failures=failures)
                if failures > self.max_failures:
                    raise
                self.ckpt.wait()
                state, step = self._rollback(state, start_step)
                step_fn = self.make_step()          # fresh compile
        self.ckpt.wait()
        return state, step, metrics
