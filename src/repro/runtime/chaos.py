"""Fault-injection menu for the elastic/fault-tolerance paths.

Every entry is a factory returning an `inject(step)` hook compatible with
`ResilientLoop.run(..., inject_failure=)` — called before each step, it
either does nothing, plants filesystem damage, or raises the failure being
simulated.  Hooks fire once: the recovery path must make forward progress
past the injection step, not loop on it.

  raise_at_step           a plain step fault (XLA error / host OOM stand-in)
  drop_device_at_step     raises DeviceLoss with the surviving device list —
                          the elastic 4->3 shrink scenario
  corrupt_checkpoint_tmp  plants a half-written tmp-<step> directory and a
                          malformed step-* entry in the checkpoint dir, the
                          debris a crash mid-save leaves; training must
                          shrug it off (latest_step ignores, gc sweeps)

`parse` maps the ``--chaos`` CLI grammar onto these:

  --chaos raise@7              step fault at step 7
  --chaos kill@5               drop 1 device at step 5
  --chaos kill@5x2             drop 2 devices at step 5
  --chaos corrupt@3            plant checkpoint debris at step 3
  --chaos corrupt@3,raise@7    hooks compose left to right

The CI chaos lane (`tests/dist_checks.py elastic`) drives the same menu
under a fault-injection matrix.
"""
from __future__ import annotations

import os
from typing import Callable, Sequence

from repro.runtime.fault_tolerance import DeviceLoss

Hook = Callable[[int], None]


def _once(step: int, fire: Callable[[int], None]) -> Hook:
    armed = {"on": True}

    def hook(s: int) -> None:
        if s == step and armed["on"]:
            armed["on"] = False
            fire(s)
    return hook


def raise_at_step(step: int,
                  message: str = "chaos: injected step fault") -> Hook:
    def fire(s):
        raise RuntimeError(f"{message} (step {s})")
    return _once(step, fire)


def drop_device_at_step(step: int, n_drop: int = 1,
                        devices: Sequence | None = None) -> Hook:
    """Simulate `n_drop` devices leaving the fleet at `step`: raises
    DeviceLoss carrying the survivors (the tail of the device list is
    dropped).  `devices` defaults to the full jax.devices() fleet — pass
    the mesh's own device list when running on a subset."""
    def fire(s):
        import jax
        devs = list(devices) if devices is not None else jax.devices()
        if n_drop >= len(devs):
            raise ValueError(f"cannot drop {n_drop} of {len(devs)} devices")
        raise DeviceLoss(devs[:-n_drop],
                         f"chaos: {n_drop} device(s) lost at step {s}")
    return _once(step, fire)


def corrupt_checkpoint_tmp(ckpt_dir: str, step: int) -> Hook:
    """Plant the debris of a crash mid-save: a torn `tmp-<step>` staging
    directory (partial arrays file, no manifest) plus a malformed
    `step-garbage` entry.  Never raises — the run must continue, with
    `latest_step` ignoring the garbage and the next gc sweeping the tmp."""
    def fire(s):
        tmp = os.path.join(ckpt_dir, f"tmp-{s}")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(b"\x00torn write")
        os.makedirs(os.path.join(ckpt_dir, "step-garbage"), exist_ok=True)
    return _once(step, fire)


def compose(*hooks: Hook) -> Hook:
    def hook(s: int) -> None:
        for h in hooks:
            h(s)
    return hook


def parse(spec: str, ckpt_dir: str | None = None,
          devices: Sequence | None = None) -> Hook:
    """`--chaos` grammar -> a composed hook (see module docstring)."""
    hooks = []
    for part in spec.split(","):
        kind, _, at = part.strip().partition("@")
        if not at:
            raise ValueError(f"chaos spec {part!r}: expected kind@step")
        if kind == "raise":
            hooks.append(raise_at_step(int(at)))
        elif kind == "kill":
            step_s, _, n_s = at.partition("x")
            hooks.append(drop_device_at_step(int(step_s),
                                             int(n_s) if n_s else 1,
                                             devices=devices))
        elif kind == "corrupt":
            if ckpt_dir is None:
                raise ValueError("chaos 'corrupt' needs the checkpoint dir")
            hooks.append(corrupt_checkpoint_tmp(ckpt_dir, int(at)))
        else:
            raise ValueError(f"unknown chaos kind {kind!r} "
                             "(raise | kill | corrupt)")
    return compose(*hooks)
