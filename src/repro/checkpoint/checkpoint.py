"""Distributed, mesh-independent checkpointing with async save and
atomic-rename commit — the fault-tolerance substrate.

Format (schema ``repro/ckpt@1``): one directory per step, containing

  manifest.json    schema tag, pytree structure, global shapes/dtypes,
                   step, caller extras, and — when the run was planned —
                   the solved NetworkPlan spec (per-layer dists, mesh
                   shape, mem_limit, config hash, calibration fingerprint;
                   see core.plan.NetworkPlan.to_spec)
  arrays.npz       the leaves as *global* numpy arrays

Saving global arrays (rather than per-shard files) makes checkpoints
**mesh-independent**: a run may restart on a different (pod, data, model)
factorization — elastic scaling.  `restore()` *reshards on restore*: each
global array is device_put under the sharding of the caller's template
leaf, so loading onto a new mesh IS the §III-C redistribution — the caller
lowers the recorded plan spec (or a freshly re-solved plan) onto the new
mesh (core.plan.plan_from_spec / plan_line) to build that template.
On a real multi-host cluster the npz write is replaced by a per-host
shard writer behind the same API (only process 0 writes here, which is
exact for a single-host CPU test rig).

Fault-tolerance contract used by repro.runtime / launch.train:
  * saves go to `<dir>/tmp-<step>` then os.replace -> `<dir>/step-<step>`
    (atomic on POSIX), so a crash mid-save never corrupts the latest good
    checkpoint;
  * `latest_step` scans only committed `step-<int>` directories — names
    that merely start with "step-" (editor droppings, a torn rename) are
    ignored rather than crashing the scan;
  * leftover `tmp-*` directories from a crash mid-save are swept at
    manager construction and on every gc pass;
  * async mode copies to host memory synchronously (cheap) and writes on a
    daemon thread, overlapping I/O with the next training steps — the
    classic checkpoint-stall mitigation;
  * `keep` rotates old checkpoints.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

SCHEMA = "repro/ckpt@1"

_STEP_RE = re.compile(r"^step-(\d+)$")


class CheckpointError(RuntimeError):
    """A checkpoint cannot be restored into the caller's state template.

    Messages carry the manifest-derived diagnosis (leaf counts, global
    shapes, the recorded plan's mesh) instead of a bare assert, so an
    elastic restart can tell "wrong architecture" from "stale directory".
    """


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self.sweep_tmp()
        self._q: queue.Queue = queue.Queue()
        self._worker = None
        self._error: list[BaseException] = []
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ---------------- public API ----------------
    def save(self, step: int, tree: Any, extra: dict | None = None,
             plan: dict | None = None):
        """Checkpoint `tree` at `step`.  `plan` (optional) is the solved
        NetworkPlan spec dict (core.plan.NetworkPlan.to_spec) recorded in
        the manifest, so a restart — possibly on a different mesh — can
        recover the distribution strategy the run was executing."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]     # device->host, sync
        manifest = {
            "schema": SCHEMA,
            "step": int(step),
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
            "plan": plan,
            "time": time.time(),
        }
        if self.async_save:
            self._raise_pending()
            self._q.put((int(step), host, manifest))
        else:
            self._write(int(step), host, manifest)

    def restore(self, tree_like: Any, step: int | None = None):
        """Restore into the structure (and shardings) of `tree_like`.

        Reshard-on-restore: arrays are stored *global*, so each leaf is
        simply device_put under the template leaf's sharding — whatever
        mesh factorization that template was built on.  Moving a run from
        a (2,2) to a (1,3) mesh is therefore the caller building the
        template under a plan lowered/re-solved on the new mesh
        (core.plan.plan_from_spec with this manifest's "plan" record) and
        restoring into it; no per-shard file layout pins the old mesh.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        manifest = self.read_manifest(step)
        path = os.path.join(self.dir, f"step-{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(tree_like)
        plan = manifest.get("plan") or {}
        hint = (f" (checkpoint recorded plan on mesh {plan.get('mesh')})"
                if plan.get("mesh") else "")
        if len(leaves) != len(manifest["shapes"]):
            raise CheckpointError(
                f"step-{step} holds {len(manifest['shapes'])} leaves but "
                f"the restore template has {len(leaves)} — different model/"
                f"optimizer structure, not a mesh change{hint}")
        out = []
        for i, ref in enumerate(leaves):
            arr = data[f"a{i}"]
            if tuple(arr.shape) != tuple(ref.shape):
                raise CheckpointError(
                    f"step-{step} leaf {i}: global shape {tuple(arr.shape)} "
                    f"vs template {tuple(ref.shape)} — checkpoints store "
                    f"GLOBAL arrays, so a mesh change alone cannot cause "
                    f"this; the architecture differs{hint}")
            if hasattr(ref, "sharding") and ref.sharding is not None:
                # reshard-on-restore: the global array lands under the
                # template's (possibly new-mesh) sharding
                out.append(jax.device_put(arr.astype(ref.dtype),
                                          ref.sharding))
            else:
                out.append(jax.device_put(arr.astype(ref.dtype)))
        return jax.tree.unflatten(treedef, out), manifest

    def read_manifest(self, step: int | None = None) -> dict | None:
        """The manifest alone (no arrays) — how an elastic restart reads
        the recorded plan spec before building any state."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        path = os.path.join(self.dir, f"step-{step}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointError(
                f"step-{step} has no readable manifest ({e}) — torn "
                f"checkpoint directory; remove it or restore an earlier "
                f"step") from e

    def latest_step(self) -> int | None:
        return max(self._committed(), default=None)

    def sweep_tmp(self) -> list[str]:
        """Remove leftover `tmp-*` staging directories (a crash mid-save
        abandons them; they are never a valid restore source)."""
        swept = []
        for d in os.listdir(self.dir):
            if d.startswith("tmp-"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
                swept.append(d)
        return swept

    def wait(self):
        """Block until pending async saves are durable."""
        self._q.join()
        self._raise_pending()

    # ---------------- internals ----------------
    def _committed(self) -> list[int]:
        """Committed step numbers; malformed names (step-abc, step-, plain
        files) are ignored instead of crashing the scan."""
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.isdir(os.path.join(self.dir, d)):
                out.append(int(m.group(1)))
        return out

    def _raise_pending(self):
        if self._error:
            raise self._error.pop()

    def _drain(self):
        while True:
            step, host, manifest = self._q.get()
            try:
                self._write(step, host, manifest)
            except BaseException as e:     # surfaced on next save()/wait()
                self._error.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host, manifest):
        tmp = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)             # atomic commit
        self._gc()

    def _gc(self):
        self.sweep_tmp()
        steps = sorted(self._committed())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)
