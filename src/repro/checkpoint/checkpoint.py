"""Distributed, mesh-independent checkpointing with async save and
atomic-rename commit — the fault-tolerance substrate.

Format: one directory per step, containing

  manifest.json    pytree structure, global shapes/dtypes, step, config hash
  arrays.npz       the leaves as *global* numpy arrays

Saving global arrays (rather than per-shard files) makes checkpoints
**mesh-independent**: a run may restart on a different (pod, data, model)
factorization — elastic scaling — and each device simply re-reads its shard.
On a real multi-host cluster the npz write is replaced by a per-host
shard writer behind the same API (only process 0 writes here, which is
exact for a single-host CPU test rig).

Fault-tolerance contract used by repro.runtime / launch.train:
  * saves go to `<dir>/tmp-<step>` then os.replace -> `<dir>/step-<step>`
    (atomic on POSIX), so a crash mid-save never corrupts the latest good
    checkpoint;
  * `latest_step` scans only committed directories;
  * async mode copies to host memory synchronously (cheap) and writes on a
    daemon thread, overlapping I/O with the next training steps — the
    classic checkpoint-stall mitigation;
  * `keep` rotates old checkpoints.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker = None
        self._error: list[BaseException] = []
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ---------------- public API ----------------
    def save(self, step: int, tree: Any, extra: dict | None = None):
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]     # device->host, sync
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
            "time": time.time(),
        }
        if self.async_save:
            self._raise_pending()
            self._q.put((int(step), host, manifest))
        else:
            self._write(int(step), host, manifest)

    def restore(self, tree_like: Any, step: int | None = None):
        """Restore into the structure (and shardings) of `tree_like`."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step-{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(tree_like)
        assert len(leaves) == len(manifest["shapes"]), \
            "checkpoint/model structure mismatch"
        out = []
        for i, ref in enumerate(leaves):
            arr = data[f"a{i}"]
            assert tuple(arr.shape) == tuple(ref.shape), \
                f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}"
            if hasattr(ref, "sharding") and ref.sharding is not None:
                out.append(jax.device_put(arr.astype(ref.dtype),
                                          ref.sharding))
            else:
                out.append(jax.device_put(arr.astype(ref.dtype)))
        return jax.tree.unflatten(treedef, out), manifest

    def latest_step(self) -> int | None:
        steps = [int(d.split("-")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step-")]
        return max(steps) if steps else None

    def wait(self):
        """Block until pending async saves are durable."""
        self._q.join()
        self._raise_pending()

    # ---------------- internals ----------------
    def _raise_pending(self):
        if self._error:
            raise self._error.pop()

    def _drain(self):
        while True:
            step, host, manifest = self._q.get()
            try:
                self._write(step, host, manifest)
            except BaseException as e:     # surfaced on next save()/wait()
                self._error.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host, manifest):
        tmp = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.replace(tmp, final)             # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step-"))
        for s in steps[:-self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)
