"""Shared small utilities."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None, legacy_check_rep=None):
    """`jax.shard_map` with a fallback to the pre-0.6 experimental API.

    New-API kwargs translate: `axis_names` (manual axes) becomes the legacy
    `auto` complement; `check_vma` maps onto `check_rep`.

    `legacy_check_rep` overrides check_rep on the legacy path only: legacy
    replication tracking cannot transpose a scan inside shard_map (cotangent
    carries have unknown rep), so bodies that are gradient-safe without
    tracking — pure ppermute rings with no psum and no replicated outputs —
    pass False here.  Bodies with psum/replicated outputs must keep tracking
    on: with check_rep=False their legacy transpose over-accumulates by the
    axis size, corrupting gradients.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy
    # axis_names (partial-manual) is intentionally dropped: legacy `auto=`
    # partial-manual trips an SPMD-partitioner check in this XLA build, and
    # our partial-manual callers only run elementwise math + collectives on
    # the manual axes, which is equally valid fully manual.
    check_rep = legacy_check_rep if legacy_check_rep is not None \
        else (check_vma if check_vma is not None else True)
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_rep)


@dataclasses.dataclass(frozen=True)
class RepPolicy:
    """The shard_map replication-checking policy one backend compiles
    under, with the reason recorded — the single source call sites quote
    instead of choosing `legacy_check_rep` ad hoc (the static-analysis
    auditor reports which policy each region compiled under)."""
    backend: str
    check_rep: bool
    reason: str

    @property
    def legacy_check_rep(self) -> bool | None:
        """The value to pass through `shard_map(..., legacy_check_rep=)`:
        None keeps the legacy default (tracking on); False disables it."""
        return None if self.check_rep else False


REP_POLICIES = {
    "xla": RepPolicy(
        "xla", check_rep=True,
        reason="legacy replication tracking stays on: bodies psum/return "
               "replicated outputs, and an untracked transpose would "
               "over-accumulate their cotangents by the axis size"),
    "pallas": RepPolicy(
        "pallas", check_rep=False,
        reason="legacy tracking cannot transpose pallas_call; the Pallas "
               "bodies are forward-only ppermute rings with no psum, which "
               "are gradient-safe without tracking"),
}


def replication_policy(backend: str) -> RepPolicy:
    """The one shard_map check_rep policy for `backend` (default: xla)."""
    return REP_POLICIES.get(backend, REP_POLICIES["xla"])


def pcast_varying(x, axes):
    """`lax.pcast(..., to='varying')` under VMA-tracking jax; identity on
    pre-VMA jax, where there is no varying/invariant distinction to mark."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None or not axes:
        return x
    return pcast(x, axes, to="varying")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def same_pads(k: int, s: int) -> tuple[int, int]:
    """TF/XLA 'SAME' padding amounts for kernel k, stride s, size % s == 0."""
    total = max(k - s, 0)
    lo = total // 2
    return lo, total - lo


def fingerprint(obj: Any) -> str:
    """Short stable content hash of a JSON-able object (dataclasses and
    tuples welcome) — how checkpoint manifests identify the model config
    and calibration a plan was solved against without embedding them."""
    import hashlib
    import json as _json
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        obj = dataclasses.asdict(obj)
    blob = _json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def tree_size_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def trimmed_mean(xs: Sequence[float], trim: float = 0.2) -> float:
    """Mean of `xs` after dropping the `trim` fraction from each tail —
    the robust estimator every benchmark timing loop in this repo uses
    (one slow outlier on a shared CI runner must not move the estimate)."""
    xs = sorted(xs)
    k = int(len(xs) * trim)
    kept = xs[k:len(xs) - k] or xs
    return sum(kept) / len(kept)


def time_fn(fn, *args, reps: int = 5, warmup: int = 1,
            trim: float = 0.2, return_samples: bool = False):
    """Wall-clock seconds per call of a jax callable (the shared benchmark
    timing loop: warmup calls absorb compilation, every timed rep blocks on
    the result, and the per-rep samples are trimmed-mean reduced).

    With `return_samples=True` returns ``(estimate, samples)`` — the raw
    per-rep seconds alongside the trimmed mean, so callers can report the
    measurement spread (p50/p95) instead of a bare point estimate.

    `benchmarks/_timing.py` re-exports this for the benchmark scripts; the
    calibrator (core.calibrate) injects it as its default timer.
    """
    import time as _time
    for _ in range(max(warmup, 1)):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    samples = []
    for _ in range(max(reps, 1)):
        t0 = _time.perf_counter()
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
        samples.append(_time.perf_counter() - t0)
    est = trimmed_mean(samples, trim)
    return (est, samples) if return_samples else est


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of `xs` (q in [0, 100]) — the spread
    statistic the benchmark columns report next to their point estimate."""
    xs = sorted(xs)
    if not xs:
        return float("nan")
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


def interleaved_samples(fns, reps: int = 5, rounds: int = 4):
    """Per-round mean seconds/call for competing callables:
    {tag: [round means]}.

    Candidates are timed in alternating rounds (A, B, A, B, ...) so
    machine-load drift during the run hits every candidate equally —
    timing each in one contiguous block makes their ratio track whatever
    else the host was doing rather than the candidates (observed 40%
    swings between *identical* programs).  Callables must already be
    compiled/warmed (call each once first) and take no arguments.

    `interleaved_min` reduces this to the comparable point estimate;
    callers wanting the spread (p50/p95 over rounds) use the samples.
    """
    import time as _time
    samples = {tag: [] for tag in fns}
    for _ in range(rounds):
        for tag, fn in fns.items():
            t0 = _time.perf_counter()
            for _ in range(max(reps, 1)):
                out = fn()
            jax.tree.leaves(out)[0].block_until_ready()
            samples[tag].append((_time.perf_counter() - t0) / max(reps, 1))
    return samples


def interleaved_min(fns, reps: int = 5, rounds: int = 4):
    """Comparative wall-clock for competing callables: {tag: seconds/call}.

    The per-tag estimate is the minimum over per-round means
    (interleaved_samples): the noise-floor round is the one where the host
    interfered least, and it is the comparable number across candidates.
    Shared by benchmarks/_timing (the benchmark scripts) and
    core.trace.trace_plan (the segmented re-execution profiler).
    """
    return {tag: min(ts)
            for tag, ts in interleaved_samples(fns, reps, rounds).items()}


def assert_no_nans(tree: Any, where: str = "") -> None:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if np.isnan(arr).any():
            raise AssertionError(f"NaN in {where}{jax.tree_util.keystr(path)}")


@dataclasses.dataclass(frozen=True)
class Precision:
    """Mixed-precision policy."""
    param_dtype: Any = jnp.float32     # master weights
    compute_dtype: Any = jnp.bfloat16  # activations / matmul inputs
    accum_dtype: Any = jnp.float32     # softmax / loss / BN stats

    def cast_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


FP32 = Precision(jnp.float32, jnp.float32, jnp.float32)
BF16 = Precision(jnp.float32, jnp.bfloat16, jnp.float32)
