"""Shared small utilities."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def same_pads(k: int, s: int) -> tuple[int, int]:
    """TF/XLA 'SAME' padding amounts for kernel k, stride s, size % s == 0."""
    total = max(k - s, 0)
    lo = total // 2
    return lo, total - lo


def tree_size_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def assert_no_nans(tree: Any, where: str = "") -> None:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if np.isnan(arr).any():
            raise AssertionError(f"NaN in {where}{jax.tree_util.keystr(path)}")


@dataclasses.dataclass(frozen=True)
class Precision:
    """Mixed-precision policy."""
    param_dtype: Any = jnp.float32     # master weights
    compute_dtype: Any = jnp.bfloat16  # activations / matmul inputs
    accum_dtype: Any = jnp.float32     # softmax / loss / BN stats

    def cast_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


FP32 = Precision(jnp.float32, jnp.float32, jnp.float32)
BF16 = Precision(jnp.float32, jnp.bfloat16, jnp.float32)
