"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) ff=13824 vocab=152064 —
GQA with QKV bias, SwiGLU, RMSNorm, rope 1e6.  [hf:Qwen/Qwen2.5; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=13824, vocab=152_064,
    qkv_bias=True, rope_theta=1e6, mlp="swiglu", norm="rmsnorm",
    tie_embeddings=False)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-smoke", n_layers=3, d_model=64, n_heads=8,
    n_kv_heads=2, head_dim=8, d_ff=160, vocab=256)
