"""seamless-m4t-large-v2 [audio]: enc-dec transformer backbone, 24L each,
d=1024 16H (kv=16) ff=8192 vocab=256206.  The speech frontend (w2v-BERT
feature extractor) is a STUB: input_specs() supplies precomputed frame
embeddings (B, S_enc, d) as encoder input.  [arXiv:2308.11596; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=8192,
    vocab=256_206, mlp="gelu", norm="layernorm", n_enc_layers=24,
    frontend="audio_stub", tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, n_enc_layers=2)
