"""gemma2-9b [dense]: 42L d=3584 16H (GQA kv=8, head_dim 256) ff=14336
vocab=256000 — alternating local(4096)/global attention, logit softcaps,
sandwich norms, GeGLU, tied + scaled embeddings.  [arXiv:2408.00118; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14336, vocab=256_000,
    rope_theta=10_000.0, attn_softcap=50.0, final_softcap=30.0,
    window=4096, layer_pattern="local_global", mlp="geglu",
    norm="rmsnorm", sandwich_norm=True, scale_embedding=True,
    tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=16)
