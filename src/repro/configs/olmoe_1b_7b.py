"""olmoe-1b-7b [moe]: 16L d=2048 16H (kv=16) expert ff=1024 vocab=50304,
64 experts top-8.  [arXiv:2409.02060; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50_304,
    rope_theta=10_000.0, mlp="swiglu", norm="rmsnorm",
    n_experts=64, top_k=8, tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="olmoe-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=64, vocab=256, n_experts=8, top_k=2)
