"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) ff=14336 vocab=32000,
8 experts top-2, sliding-window attention (4096).  [arXiv:2401.04088; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32_000,
    rope_theta=1e6, window=4096, layer_pattern="swa", mlp="swiglu",
    norm="rmsnorm", n_experts=8, top_k=2, tie_embeddings=False)

SMOKE = dataclasses.replace(
    CONFIG, name="mixtral-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=96, vocab=256, window=16,
    n_experts=4, top_k=2)
