"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) ff=14336 vocab=131072 —
mistral-nemo decoder backbone; the pixtral-ViT frontend is a STUB:
input_specs() supplies precomputed patch embeddings (B, S_img, d) prefixed
to the text tokens.  [hf:mistralai/Pixtral-12B-2409; unverified]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131_072,
    rope_theta=1e9, mlp="swiglu", norm="rmsnorm", tie_embeddings=False,
    frontend="vit_stub", frontend_len=1024)

SMOKE = dataclasses.replace(
    CONFIG, name="pixtral-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, frontend_len=8)
