"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads in every block; full
attention on layers {0, mid, last}, SWA elsewhere.  [arXiv:2411.13676; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32_001,
    rope_theta=10_000.0, window=1024, layer_pattern="hymba", mlp="swiglu",
    norm="rmsnorm", ssm_state=16, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="hymba-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=16,
    ssm_state=8, ssm_head_dim=16)
