"""Architecture registry: `get(name)` -> config; `--arch <id>` everywhere.

Each assigned architecture lives in src/repro/configs/<id>.py exposing
CONFIG (full size, dry-run only) and SMOKE (reduced same-family config for
CPU tests).  The paper's own models (resnet50, mesh1k, mesh2k) register too.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "gemma2_9b", "qwen2_5_14b", "qwen1_5_0_5b", "olmo_1b", "mixtral_8x7b",
    "olmoe_1b_7b", "hymba_1_5b", "pixtral_12b", "mamba2_780m",
    "seamless_m4t_large_v2",
]
CNN_ARCHS = ["resnet50", "mesh1k", "mesh2k"]

# the archs the §V-C strategy optimizer can solve (--strategy auto,
# calibrate, --mem-limit): the CNN family whose layer DAGs have a candidate
# distribution space.  The LM seed configs above stay loadable/trainable
# under the uniform sharding but are quarantined out of every solver
# entrypoint — launch.train errors (not warns) on `--strategy auto` + LM.
SOLVABLE_ARCHS = list(CNN_ARCHS)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS + CNN_ARCHS}
_ALIASES.update({
    "gemma2-9b": "gemma2_9b", "qwen2.5-14b": "qwen2_5_14b",
    "qwen1.5-0.5b": "qwen1_5_0_5b", "olmo-1b": "olmo_1b",
    "mixtral-8x7b": "mixtral_8x7b", "olmoe-1b-7b": "olmoe_1b_7b",
    "hymba-1.5b": "hymba_1_5b", "pixtral-12b": "pixtral_12b",
    "mamba2-780m": "mamba2_780m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
})

# shape cells assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# archs whose 500K-token *prefill* is quadratic (pure full attention at
# long range); their long_500k decode cell is lowered but flagged —
# DESIGN.md §Arch-applicability.
FULL_ATTN_500K = {"qwen2_5_14b", "qwen1_5_0_5b", "olmo_1b", "olmoe_1b_7b",
                  "pixtral_12b", "seamless_m4t_large_v2"}


def canon(name: str) -> str:
    return _ALIASES.get(name, name)


def get(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.SMOKE if smoke else mod.CONFIG
