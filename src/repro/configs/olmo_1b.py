"""olmo-1b [dense]: 16L d=2048 16H (kv=16) ff=8192 vocab=50304 —
non-parametric LayerNorm, SwiGLU, untied head.  [arXiv:2402.00838; hf]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=8192, vocab=50_304,
    rope_theta=10_000.0, mlp="swiglu", norm="nonparam_ln",
    tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="olmo-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=256, vocab=256)
