"""mamba2-780m [ssm]: 48L d=1536, attention-free SSD (state-space duality),
ssm_state=128, headdim=64, expand=2, vocab=50280.  [arXiv:2405.21060]"""
import dataclasses
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=50_280,
    mlp="none", norm="rmsnorm", ssm_state=128, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=128, tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=4, d_model=64, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
