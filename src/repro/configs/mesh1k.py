"""1K mesh-tangling model (paper §VI): 6 blocks x 3 convs, 1024^2 x 18."""
from repro.models.cnn.meshnet import MESH1K as CONFIG, MeshNetConfig  # noqa: F401 — registry re-export
SMOKE = MeshNetConfig("mesh1k-smoke", input_hw=64, in_channels=4,
                      convs_per_block=1, widths=(8, 16, 16))
