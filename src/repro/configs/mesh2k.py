"""2K mesh-tangling model (paper §VI): 6 blocks x 5 convs, 2048^2 x 18 —
activations exceed one 16 GB GPU at batch 1 (the memory headline)."""
from repro.models.cnn.meshnet import MESH2K as CONFIG, MeshNetConfig  # noqa: F401 — registry re-export
SMOKE = MeshNetConfig("mesh2k-smoke", input_hw=64, in_channels=4,
                      convs_per_block=2, widths=(8, 16, 16))
