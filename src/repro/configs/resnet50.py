"""ResNet-50 on ImageNet-1K — the paper's §VI-B2 workload."""
from repro.models.cnn.resnet import RESNET50 as CONFIG, ResNetConfig  # noqa: F401 — registry re-export
SMOKE = ResNetConfig(name="resnet-smoke", input_hw=32, n_classes=10,
                     stages=(1, 1, 1, 1), widths=(4, 8, 16, 16))
