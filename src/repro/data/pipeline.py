"""Data pipeline: deterministic, shardable, prefetching.

The paper trains on (a) ImageNet-1K and (b) a hydrodynamics mesh-tangling
dataset (1K/2K 18-channel images, 10k samples).  Neither ships with this
container, so the pipeline serves *synthetic* samples that match the paper's
shapes and statistics exactly ("For performance benchmarks on this problem,
we use synthetic data", §VI) — while keeping the production structure:

  * per-step deterministic RNG (restart-safe: step index -> sample batch,
    so checkpoint/restart replays the identical stream);
  * host-side generation on a prefetch thread (double buffering), the CPU
    stand-in for a real input service;
  * global-batch construction with the train loop placing shards via
    jax.device_put under the mesh sharding (each host would materialize
    only its slice on a real cluster).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


def synthetic_mesh_batch(step: int, batch: int, hw: int, channels: int = 18,
                         out_hw: int | None = None) -> dict:
    """Mesh-tangling lookalike: smooth random fields (state variables) and a
    per-pixel tangle mask on the prediction grid."""
    rng = np.random.default_rng(1234 + step)
    x = rng.standard_normal((batch, hw, hw, channels), dtype=np.float32)
    out_hw = out_hw or hw // 64
    y = (rng.random((batch, out_hw, out_hw, 1)) < 0.1).astype(np.float32)
    return {"image": x, "label": y}


def synthetic_imagenet_batch(step: int, batch: int, hw: int = 224,
                             n_classes: int = 1000) -> dict:
    rng = np.random.default_rng(4321 + step)
    x = rng.standard_normal((batch, hw, hw, 3), dtype=np.float32)
    y = rng.integers(0, n_classes, size=(batch,), dtype=np.int32)
    return {"image": x, "label": y}


def synthetic_lm_batch(step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng(9876 + step)
    tokens = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Prefetcher:
    """Double-buffered host-side prefetch of a step-indexed batch factory.

    Queue entries are tagged with their step index, and `get(step)` is
    step-addressable: a rollback (fault recovery replaying from the last
    checkpoint) seeks the stream backward and the filler thread restarts
    at the requested step, so the replay consumes the *identical* batches
    the failed attempt did — the determinism the resilient loop's contract
    promises.  Requests ahead of the stream skip stale entries forward.
    """

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._make = make_batch
        self._depth = depth
        self._start(start_step)

    def _start(self, step: int):
        self._q: queue.Queue = queue.Queue(maxsize=self._depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._fill,
                                   args=(step, self._q, self._stop),
                                   daemon=True)
        self._t.start()

    def _fill(self, s: int, q: queue.Queue, stop: threading.Event):
        while not stop.is_set():
            try:
                q.put((s, self._make(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def seek(self, step: int):
        """Restart the stream at `step` (rollback rewind)."""
        self._stop.set()
        self._t.join()
        self._start(step)

    def get(self, step: int) -> dict:
        """The batch for exactly `step`: drains forward past stale entries,
        rewinds the stream when `step` is behind it."""
        while True:
            s, b = self._q.get()
            if s == step:
                return b
            if s > step:
                self.seek(step)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()[1]

    def close(self):
        self._stop.set()
