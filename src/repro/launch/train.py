"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck [--data 2 --model 2]

Runs the resilient loop (checkpoint/restart, straggler monitor) around the
jit'd train step.  On this CPU container use --smoke (reduced config); the
full configs are for the TPU pods the dry-run targets.  `--arch mesh1k/
mesh2k/resnet50 --smoke` trains the paper's CNN workloads under hybrid
sample x spatial parallelism; add `--strategy auto` to run the paper's §V-C
strategy optimizer at startup and execute its per-layer distribution plan
(with automatic inter-layer resharding) instead of the uniform default.
The solved plan may mix sample, spatial and channel/filter (§III-D) layers
— including H/W split over *products* of mesh axes (core.halo) and
CF x spatial compositions whose halo exchange and CF collective share one
shard_map (core.channel_conv), the decompositions 16x16 meshes need; the
CF mode ('filter' vs 'channel') is picked per layer from the
AG(x)-vs-RS(y) payload sizes.  Pass --no-cf to restrict the search to
sample/spatial for A/B comparison.
"""
from __future__ import annotations

import argparse
import functools
import logging
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import pipeline
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes, elastic_factorization, make_mesh
from repro.optim.optimizer import adamw, sgd, warmup_cosine
from repro.runtime.fault_tolerance import ResilientLoop, StragglerMonitor
from repro.train.metrics import MetricsLogger, debug_nan_check
from repro.train.train_loop import TrainStepConfig, make_train_step
from repro.utils import BF16, FP32, human_count, tree_num_params

logging.basicConfig(level=logging.INFO)


def parse_mem_limit(value) -> float | None:
    """--mem-limit BYTES|auto -> bytes/device (None = unconstrained).
    'auto' detects the live device's capacity (accelerators report it via
    memory_stats; hosts fall back to a MemAvailable share —
    core.calibrate.detect_mem_capacity)."""
    if value is None:
        return None
    if str(value).lower() == "auto":
        from repro.core.calibrate import detect_mem_capacity
        return detect_mem_capacity()
    return float(value)


def build_cnn_plan(args, arch, cfg, mesh, ba):
    """--strategy uniform: the legacy one-ConvSharding-everywhere plan.
    --strategy auto: run the §V-C optimizer on the arch's layer DAG and
    compile the solved per-layer distributions (core.plan).  With
    --calibrate the optimizer solves on *measured* costs: a calibration
    (core.calibrate) is loaded from the given path when it exists, else
    microbenchmarked on the live backend and written there.  With
    --mem-limit the solve is memory-aware: min-time subject to every
    layer's resident set (and the network peak) fitting the per-device
    capacity — the paper's §VI Table-2 'unreachable workloads' lever."""
    from repro.core import plan as plan_lib
    from repro.core.perfmodel import TPU_V5E
    from repro.core.spatial_conv import ConvSharding
    from repro.utils import human_bytes
    if arch == "resnet50":
        from repro.models.cnn import resnet as M
        specs = M.layer_specs(args.batch, cfg)
        graph = M.resnet_graph(args.batch, cfg)
    else:
        from repro.models.cnn import meshnet as M
        specs = M.layer_specs(cfg, args.batch)
        graph = None
    machine, table, calib_fp = TPU_V5E, None, None
    if args.calibrate and args.strategy != "auto":
        # measured costs only feed the solver — don't spend minutes
        # microbenchmarking for a plan that ignores them
        logging.warning("--calibrate only affects --strategy auto; "
                        "skipping calibration for --strategy %s",
                        args.strategy)
    elif args.calibrate:
        from repro.core import calibrate as calib
        from repro.utils import fingerprint
        t0 = time.time()
        # honor --no-cf: don't spend startup time measuring CF candidate
        # shapes and collective sizes the solver is forbidden to pick
        cal = calib.load_or_run(args.calibrate, specs, mesh,
                                allow_channel_filter=not args.no_cf)
        print(f"calibration ready ({time.time() - t0:.2f}s, "
              f"{len(cal.table)} table entries)")
        machine, table = cal.machine, cal.table
        calib_fp = fingerprint(cal.to_json())
    mem_limit = parse_mem_limit(args.mem_limit)
    if mem_limit and args.strategy != "auto":
        logging.warning("--mem-limit constrains the --strategy auto solve "
                        "only; the uniform plan is not validated")
    if args.strategy == "auto":
        t0 = time.time()
        allow_cf = not args.no_cf
        if mem_limit:
            print(f"memory limit: {human_bytes(mem_limit)}/device")
        if graph is not None:
            plan = plan_lib.plan_graph(machine, graph, specs, mesh,
                                       table=table,
                                       allow_channel_filter=allow_cf,
                                       mem_limit=mem_limit,
                                       search=args.search)
        else:
            plan = plan_lib.plan_line(machine, specs, mesh, table=table,
                                      allow_channel_filter=allow_cf,
                                      mem_limit=mem_limit,
                                      search=args.search)
        print(f"strategy optimizer ({time.time() - t0:.2f}s):")
        print(plan.describe())
    else:
        plan = plan_lib.NetworkPlan.uniform(
            ConvSharding(batch_axes=ba, h_axis="model"),
            [l.name for l in specs])
    return plan, specs, calib_fp


def plan_record(args, cfg, extras, mesh) -> dict | None:
    """The ``repro/plan@1`` spec recorded in every checkpoint manifest:
    the solved per-layer dists + the solve's inputs (mesh shape,
    mem_limit, config hash, calibration fingerprint) — what an elastic
    restart lowers/re-solves on a new mesh (core.plan.plan_from_spec)."""
    plan = extras.get("plan")
    if plan is None:
        return None
    from repro.utils import fingerprint
    return plan.to_spec(
        mesh, mem_limit=parse_mem_limit(args.mem_limit),
        config_hash=fingerprint(cfg),
        calibration_fingerprint=extras.get("calib_fp"))


def on_mesh(tree, mesh):
    """Pin every leaf to `mesh`: leaves already placed there (e.g. params
    under their fsdp specs) pass through; everything else — notably the
    scalar optimizer counters opt.init leaves uncommitted on one device —
    is replicated.  A restore template must be *fully* committed to its
    mesh or reshard-on-restore would re-commit stray leaves to a single
    device and the jitted step would see mixed device sets."""
    devs = set(np.asarray(mesh.devices).ravel().tolist())
    def fix(x):
        sh = getattr(x, "sharding", None)
        if sh is not None and set(sh.device_set) == devs:
            return x
        return jax.device_put(x, NamedSharding(mesh, P()))
    return jax.tree.map(fix, tree)


def build(args, mesh):
    arch = registry.canon(args.arch)
    ba = batch_axes(mesh)
    extras = {"arch": arch, "plan": None, "specs": None, "layer_names": None,
              "calib_fp": None}
    if arch in registry.CNN_ARCHS:
        cfg = registry.get(arch, smoke=args.smoke)
        plan, specs, calib_fp = build_cnn_plan(args, arch, cfg, mesh, ba)
        extras.update(plan=plan, specs=specs, calib_fp=calib_fp)
        if arch == "resnet50":
            from repro.models.cnn import resnet as M
            mk = lambda s: pipeline.synthetic_imagenet_batch(
                s, args.batch, cfg.input_hw, cfg.n_classes)
        else:
            from repro.models.cnn import meshnet as M
            extras["layer_names"] = M.layer_names(cfg)
            mk = lambda s: pipeline.synthetic_mesh_batch(
                s, args.batch, cfg.input_hw, cfg.in_channels,
                out_hw=cfg.out_hw)
        loss = functools.partial(M.loss_fn, cfg=cfg, plan=plan, mesh=mesh)
        params = M.init(jax.random.PRNGKey(args.seed), cfg)
        opt = sgd(warmup_cosine(args.lr, 10, args.steps), momentum=0.9)
        prec = FP32
        first = specs[0]
        im_spec = plan.input_spec(first.name, first.h, first.w, first.k,
                                  first.s, mesh)

        def put(b):
            out = {}
            for k, v in b.items():
                spec = im_spec if k == "image" else P(ba)
                out[k] = jax.device_put(v, NamedSharding(mesh, spec))
            return out
    else:
        from repro.models.lm import transformer as T
        from repro.models.lm.modules import ShardCtx
        if args.strategy == "auto":
            # quarantine, not silence: the §V-C optimizer covers the CNN
            # archs (registry.SOLVABLE_ARCHS); an LM arch asking for a
            # solved plan would silently train uniform otherwise
            raise SystemExit(
                f"--strategy auto covers the solvable CNN archs "
                f"{registry.SOLVABLE_ARCHS}; {arch!r} is an LM arch the "
                f"§V-C optimizer has no candidate space for (drop "
                f"--strategy auto to train it with the uniform sharding)")
        if args.calibrate:
            logging.warning("--calibrate covers the CNN archs only; "
                            "ignored for %s", arch)
        if args.mem_limit:
            logging.warning("--mem-limit covers the CNN archs only; "
                            "ignored for %s", arch)
        cfg = registry.get(arch, smoke=args.smoke)
        ctx = ShardCtx(mesh=mesh, seq_axis="model", batch_axes=ba)
        loss = functools.partial(T.loss_fn, cfg=cfg, ctx=ctx,
                                 remat=args.remat)
        params = T.init(jax.random.PRNGKey(args.seed), cfg)
        opt = adamw(warmup_cosine(args.lr, 20, args.steps))
        prec = BF16 if args.bf16 else FP32
        mk = lambda s: pipeline.synthetic_lm_batch(
            s, args.batch, args.seq, cfg.vocab)

        def put(b):
            return {k: jax.device_put(v, NamedSharding(mesh, P(ba, "model")))
                    for k, v in b.items()}

    pspecs = SH.fsdp_tree_specs(params, mesh)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, pspecs)
    return cfg, params, opt, loss, mk, put, prec, extras


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mesh1k",
                    help="architecture id (registry); defaults to the "
                         "paper's 1K mesh-tangling CNN")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--strategy", default="uniform",
                    choices=["uniform", "auto"],
                    help="CNN parallelization: 'uniform' applies one hybrid "
                         "ConvSharding to every layer (legacy); 'auto' runs "
                         "the paper's §V-C optimizer at startup and executes "
                         "the solved per-layer plan with resharding — "
                         "including §III-D channel/filter layers, CF x "
                         "spatial compositions and product-axis spatial "
                         "splits (core.channel_conv, core.halo) unless "
                         "--no-cf")
    ap.add_argument("--no-cf", action="store_true",
                    help="exclude channel/filter candidates from --strategy "
                         "auto (sample/spatial only, the pre-CF behavior)")
    ap.add_argument("--search", default="greedy",
                    metavar="greedy|beam[:N]|hillclimb",
                    help="--strategy auto search mode: 'greedy' is the "
                         "paper's one-target-per-axis DP (default); "
                         "'beam[:N]' widens the candidate space (mesh axes "
                         "may go unassigned) and, on branchy DAGs, replaces "
                         "longest-path-first with a reshard-cost-aware "
                         "global beam DP of width N (default 4); "
                         "'hillclimb' is the stochastic local-search "
                         "baseline over the same wide space.  An elastic "
                         "remesh re-solves with the same mode")
    ap.add_argument("--calibrate", nargs="?", const="BENCH_calibration.json",
                    default=None, metavar="PATH",
                    help="solve --strategy auto on measured costs: "
                         "microbenchmark local conv at this arch's layer "
                         "shapes plus halo/collective primitives on the "
                         "live backend, fit Machine constants and an "
                         "EmpiricalTable (core.calibrate), and feed them to "
                         "the §V-C solver.  PATH (default "
                         "BENCH_calibration.json) is loaded when it exists, "
                         "else written — CNN archs only")
    ap.add_argument("--mem-limit", nargs="?", const="auto", default=None,
                    metavar="BYTES|auto",
                    help="per-device memory capacity for --strategy auto: "
                         "the §V-C solve becomes min-time subject to every "
                         "layer's resident set fitting (core.perfmodel."
                         "layer_memory), unlocking workloads sample "
                         "parallelism cannot fit (paper §VI Table 2).  "
                         "'auto' (the bare-flag default) detects the live "
                         "device capacity; an integer sets a synthetic "
                         "limit in bytes — CNN archs only")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pod", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--bf16", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--pod-compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--elastic", action="store_true",
                    help="survive device loss: on a DeviceLoss step fault "
                         "the loop rebuilds the mesh from the surviving "
                         "devices (launch.mesh.elastic_factorization), "
                         "re-solves the plan on the shrunk mesh under the "
                         "same --mem-limit, reshards the last checkpoint "
                         "onto it and resumes the deterministic batch "
                         "stream — CapacityError surfaces with the usual "
                         "diagnostics when nothing fits the survivors")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault injection (runtime.chaos): e.g. 'raise@7' "
                         "(step fault), 'kill@5' / 'kill@5x2' (drop "
                         "devices -> DeviceLoss; pair with --elastic), "
                         "'corrupt@3' (plant checkpoint-tmp debris); "
                         "comma-compose")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics", nargs="?", const="METRICS.jsonl",
                    default=None, metavar="PATH",
                    help="write structured JSONL step records (loss, "
                         "step time, samples/s) to PATH (default "
                         "METRICS.jsonl) next to the terminal echo")
    ap.add_argument("--profile", nargs="?", const="BENCH_step_trace.json",
                    default=None, metavar="PATH",
                    help="profile instead of train: measure every plan "
                         "layer's isolated fwd/bwd cost (core.trace."
                         "trace_plan), print the predicted-vs-measured "
                         "attribution table, write the StepTrace JSON to "
                         "PATH (default BENCH_step_trace.json) plus a "
                         "Chrome-trace timeline next to it, then exit — "
                         "meshnet archs (mesh1k/mesh2k) only")
    ap.add_argument("--audit", action="store_true",
                    help="static fail-fast gate before training: lint the "
                         "built plan and audit its priced collectives "
                         "against the traced step (repro.analysis, "
                         "lowering-only — no timed work); abort when any "
                         "error-severity finding shows costed != executed "
                         "— meshnet archs (mesh1k/mesh2k) only")
    ap.add_argument("--debug-nans", action="store_true",
                    help="check loss/grad_norm for NaN/inf every step and "
                         "fail fast naming the first offending layer "
                         "(train.metrics.debug_nan_check)")
    args = ap.parse_args()
    try:
        from repro.core.strategy import parse_search
        parse_search(args.search)
    except ValueError as e:
        ap.error(str(e))

    mesh = make_mesh(data=args.data, model=args.model, pod=args.pod)
    cfg, params, opt, loss, mk, put, prec, extras = build(args, mesh)
    print(f"arch={cfg.name} params={human_count(tree_num_params(params))} "
          f"mesh={dict(mesh.shape)}")

    if args.audit:
        audit_gate(args, cfg, mesh, extras)

    if args.profile:
        profile(args, cfg, params, mk, put, mesh, extras)
        return

    tstep = make_train_step(
        lambda p, b: loss(p, b), opt, mesh,
        TrainStepConfig(grad_accum=args.grad_accum, precision=prec,
                        pod_compression=args.pod_compression))
    ck = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
    state = on_mesh((params, opt.init(params), None), mesh)
    start = 0
    restored, manifest = ck.restore(state) if ck.latest_step() else (None,
                                                                     None)
    if restored is not None:
        state, start = restored, manifest["extra"]["step"]
        print(f"resumed from step {start}")
        rec = manifest.get("plan")
        if rec and rec.get("mesh") and rec["mesh"] != dict(mesh.shape):
            print(f"reshard-on-restore: checkpoint recorded mesh "
                  f"{rec['mesh']}, restoring onto {dict(mesh.shape)} "
                  f"(global arrays re-placed under the current plan)")

    pf = pipeline.Prefetcher(mk, start_step=start)
    mon = StragglerMonitor()
    t0 = time.time()
    losses = []
    mlog = MetricsLogger(args.metrics)
    mlog.log_run(arch=cfg.name, n_params=tree_num_params(params),
                 mesh=dict(mesh.shape), batch=args.batch, steps=args.steps,
                 strategy=args.strategy, start_step=start)

    # mutable execution context: an elastic remesh swaps the compiled step,
    # the batch placer and the recorded plan spec without rebuilding the
    # closures the loop already holds
    ctx = {"tstep": tstep, "put": put, "layer_names": extras["layer_names"],
           "plan_spec": plan_record(args, cfg, extras, mesh)}

    def make_step():
        def run(state, step):
            p, o, ef = state
            b = ctx["put"](pf.get(step))
            p, o, ef, m = ctx["tstep"](p, o, ef, b)
            losses.append(float(m["loss"]))
            if args.debug_nans:
                host = {k: float(v) for k, v in m.items()
                        if k in ("loss", "grad_norm")}
                debug_nan_check(step, host, p, ctx["layer_names"])
            dt = (time.time() - t0) / (len(losses) or 1)
            mlog.log_step(step, losses[-1], step_time_s=dt,
                          samples_per_s=args.batch / dt if dt else None,
                          echo=step % args.log_every == 0)
            return (p, o, ef), m
        return run

    def remesh(survivors):
        """Elastic restart: rebuild mesh + plan + step over the survivors.

        Re-runs the full build (so --strategy auto re-solves under the same
        --mem-limit on the shrunk mesh — CapacityError surfaces here when
        nothing fits) and returns the step factory plus a state template
        sharded under the new mesh; the loop reshards-on-restore the last
        checkpoint's global arrays into it."""
        data, model = elastic_factorization(len(survivors),
                                            batch=args.batch)
        print(f"elastic restart: {len(survivors)} survivors -> mesh "
              f"data={data} model={model}; re-solving plan")
        new_mesh = make_mesh(data=data, model=model,
                             devices=list(survivors))
        cfg2, params2, opt2, loss2, _, put2, prec2, extras2 = \
            build(args, new_mesh)
        ctx["tstep"] = make_train_step(
            lambda p, b: loss2(p, b), opt2, new_mesh,
            TrainStepConfig(grad_accum=args.grad_accum, precision=prec2,
                            pod_compression=args.pod_compression))
        ctx["put"] = put2
        ctx["layer_names"] = extras2["layer_names"]
        ctx["plan_spec"] = plan_record(args, cfg2, extras2, new_mesh)
        return make_step, on_mesh((params2, opt2.init(params2), None),
                                  new_mesh)

    loop = ResilientLoop(ckpt=ck, make_step=make_step,
                         ckpt_every=args.ckpt_every,
                         remesh=remesh if args.elastic else None,
                         metrics=mlog,
                         plan_spec=lambda: ctx["plan_spec"])
    inject = None
    if args.chaos:
        from repro.runtime import chaos
        inject = chaos.parse(args.chaos, ckpt_dir=args.ckpt_dir,
                             devices=list(mesh.devices.flat))
    state, step, metrics = loop.run(state, start, args.steps, monitor=mon,
                                    inject_failure=inject)
    ck.save(step, state, extra={"step": step}, plan=ctx["plan_spec"])
    ck.wait()
    pf.close()
    mlog.log_done(step, loss=losses[-1], straggler=mon.stats)
    mlog.close()
    print(f"done at step {step}; final loss {losses[-1]:.4f}; "
          f"straggler stats {mon.stats}")


def audit_gate(args, cfg, mesh, extras):
    """--audit: prove costed == executed before spending a single step.

    Lints the built plan (repro.analysis.lint_plan via NetworkPlan.audit)
    and joins its priced collective inventory against the traced jaxpr of
    the real train step — all lowering-only.  Any error-severity finding
    aborts the run; warnings and infos print and training proceeds."""
    from repro import analysis
    if extras["layer_names"] is None:
        raise SystemExit("--audit covers the meshnet archs (mesh1k/"
                         "mesh2k) — the collective auditor walks "
                         "meshnet.loss_fn")
    t0 = time.time()
    findings = extras["plan"].audit(extras["specs"], mesh, cfg=cfg,
                                    overlap=True, hlo=False)
    errs = analysis.error_count(findings)
    print(f"plan audit: {len(findings)} finding(s), {errs} error(s) "
          f"({time.time() - t0:.1f}s, lowering-only)")
    print(analysis.format_findings(findings))
    if errs:
        raise SystemExit(
            f"--audit: {errs} error-severity finding(s) — the plan's "
            f"costed collectives do not match the traced step; refusing "
            f"to train on it")


def profile(args, cfg, params, mk, put, mesh, extras):
    """--profile: segmented per-layer cost measurement instead of training.

    Runs core.trace.trace_plan on the built plan, prints the
    predicted-vs-measured attribution table (when the plan carries a
    perf-model report, i.e. --strategy auto), and writes the StepTrace
    JSON (attribution embedded in meta) plus a Chrome-trace timeline."""
    from repro.core.trace import format_attribution, trace_plan
    if extras["layer_names"] is None:
        raise SystemExit("--profile covers the meshnet archs "
                         "(mesh1k/mesh2k) — the segmented profiler walks "
                         "meshnet.layer_fns")
    plan = extras["plan"]
    batch = put(mk(0))
    t0 = time.time()
    trace = trace_plan(plan, params, batch, cfg=cfg, mesh=mesh,
                       reps=2, rounds=2)
    print(f"profiled {len(trace.layers)} layers in {time.time() - t0:.1f}s "
          f"(step fwd+bwd {trace.step['fwd_bwd_s']*1e3:.3f} ms, "
          f"layer sum {trace.layer_sum_s*1e3:.3f} ms)")
    if plan.predicted and "layer_costs" in plan.predicted:
        report = plan.attribution_report(trace)
        trace.meta["attribution"] = report
        print(format_attribution(report))
    else:
        print("no perf-model prediction on this plan (use --strategy auto "
              "for the predicted-vs-measured attribution)")
    trace.save(args.profile)
    chrome = args.profile[:-5] if args.profile.endswith(".json") \
        else args.profile
    trace.save_chrome(chrome + ".chrome.json")
    print(f"wrote {args.profile} and {chrome}.chrome.json")


if __name__ == "__main__":
    main()
