"""Sharding rules: how every tensor of every arch maps onto the mesh.

Baseline (paper-faithful hybrid sample x spatial + FSDP memory sharding):
  * batch dims  -> ("pod", "data")
  * sequence/H  -> "model"            (the paper's fine-grained axis)
  * weights     -> largest dim FSDP-sharded over "data", replicated on
                   "model" (the paper replicates weights; FSDP is the
                   memory adaptation for 9-46B params, DESIGN.md §2)
  * optimizer   -> inherits parameter shardings (ZeRO-1)

The hillclimbed variants (EXPERIMENTS.md §Perf) override pieces of this —
e.g. TP on heads/ffn over "model", expert sharding for MoE.
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def fsdp_tree_specs(tree, mesh, axes=("data",)):
    """FSDP/ZeRO PartitionSpec for every leaf: the largest dim divisible by
    the data-axis size is sharded over 'data'; small tensors replicate.

    Weights stay REPLICATED across the model axis — exactly the paper's
    design (w replicated on every processor of a spatial group, §III-A) —
    and shard only across the sample-parallel groups, which also shards
    optimizer state (ZeRO).  Probing showed that co-sharding weights over
    the busy model axis makes XLA gather entire stacked layer arrays
    around the scan (hundreds of GiB of temps); archs whose weights still
    don't fit this way (mixtral-8x7b) are exactly the ones the hillclimbed
    expert/vocab-parallel variant (§Perf) fixes."""
    shape_map = dict(mesh.shape)
    data = ("data",) if "data" in shape_map else ()
    n_data = shape_map.get("data", 1)

    def spec(x):
        if not x.shape or x.size < 2 ** 14 or not data:
            return P()
        for d in sorted(range(x.ndim), key=lambda d: -x.shape[d]):
            if x.shape[d] % n_data == 0 and x.shape[d] >= n_data:
                s = [None] * x.ndim
                s[d] = "data"
                return P(*s)
        return P()
    return jax.tree.map(spec, tree)


def zero1_tree_specs(tree, mesh, axes=("data", "model")):
    """Optimizer-state sharding over BOTH axes (ZeRO-1 over all chips).

    Unlike weights, mu/nu are touched only in the (scan-free) update at the
    step's end, so the 2-axis sharding that pathologically regathers
    weights around the layer scan is safe here — and halves-squared the
    largest fp32 residency (4.6 GiB -> 0.3 GiB/device for gemma2-9b)."""
    shape_map = dict(mesh.shape)
    ax = tuple(a for a in axes if a in shape_map)
    n = int(np.prod([shape_map[a] for a in ax])) if ax else 1
    n_data = shape_map.get("data", 1)

    def spec(x):
        if not x.shape or x.size < 2 ** 14:
            return P()
        for d in sorted(range(x.ndim), key=lambda d: -x.shape[d]):
            if x.shape[d] % n == 0 and x.shape[d] >= n:
                s = [None] * x.ndim
                s[d] = ax
                return P(*s)
        for d in sorted(range(x.ndim), key=lambda d: -x.shape[d]):
            if x.shape[d] % n_data == 0 and x.shape[d] >= n_data:
                s = [None] * x.ndim
                s[d] = "data"
                return P(*s)
        return P()
    return jax.tree.map(spec, tree)


def with_sharding(tree, mesh, specs):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(
            x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        tree, specs)


def lm_batch_spec(mesh, kind: str) -> dict[str, P]:
    ba = batch_axes(mesh)
    if kind == "train":
        return {"tokens": P(ba, "model"), "labels": P(ba, "model")}
    if kind == "prefill":
        return {"tokens": P(ba, "model")}
    if kind == "decode":
        return {"tokens": P(ba, None)}
    raise ValueError(kind)


def kv_cache_specs(cache_tree, mesh, batch_sharded: bool, seq_axes):
    """Cache: (layers, B, S, Hkv, hd) -> P(None, batch, seq_axes, ...);
    SSM states (layers, B, H, p, n) replicated over model (tiny)."""
    ba = batch_axes(mesh) if batch_sharded else None

    def spec(x):
        if x.ndim == 5 and x.shape[2] > x.shape[3]:      # k/v cache
            return P(None, ba, seq_axes, None, None)
        if x.ndim == 5:                                   # ssm state
            return P(None, ba, None, None, None)
        if x.ndim == 4:                                   # conv tail
            return P(None, ba, None, None)
        return P()
    return jax.tree.map(spec, cache_tree)
