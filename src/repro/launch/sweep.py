"""Run the full dry-run matrix: 10 archs x 4 shapes x {1-pod, 2-pod} plus
the paper's CNN workloads.  One subprocess per cell (fresh XLA, fresh
device-count env); artifacts are JSON files consumed by benchmarks/roofline
and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.sweep [--only-missing] [--pods 1,2]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import registry


def cell_tag(arch, shape, multi_pod, variant="base"):
    tag = f"{arch}-{shape}-{'pod2' if multi_pod else 'pod1'}"
    return tag if variant == "base" else f"{tag}-{variant}"


def run_one(arch, shape, multi_pod, out_dir, variant="base",
            timeout=1200) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out_dir]
    if multi_pod:
        cmd.append("--multi-pod")
    if variant != "base":
        cmd += ["--variant", variant]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=os.path.dirname(os.path.dirname(
                           os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))))
    ok = r.returncode == 0
    if not ok:
        err = (r.stderr or "").strip().splitlines()
        fail = {"arch": arch, "shape": shape, "variant": variant,
                "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
                "error": err[-15:] if err else ["(no stderr)"]}
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir,
                               cell_tag(arch, shape, multi_pod, variant)
                               + ".json"), "w") as f:
            json.dump(fail, f, indent=1)
    print(f"[{time.strftime('%H:%M:%S')}] {arch:24s} {shape:12s} "
          f"{'pod2' if multi_pod else 'pod1'} "
          f"{'OK' if ok else 'FAIL'} ({time.time()-t0:.0f}s)", flush=True)
    return {"ok": ok}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--pods", default="1,2")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--include-cnn", action="store_true", default=True)
    args = ap.parse_args()
    pods = [p == "2" for p in args.pods.split(",")]

    cells = []
    for arch in registry.ARCHS:
        for shape in registry.SHAPES:
            for mp in pods:
                cells.append((arch, shape, mp))
    if args.include_cnn:
        for arch in registry.CNN_ARCHS:
            for mp in pods:
                cells.append((arch, "cnn", mp))

    n_ok = n_fail = n_skip = 0
    for arch, shape, mp in cells:
        path = os.path.join(args.out, cell_tag(arch, shape, mp) + ".json")
        if args.only_missing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    n_skip += 1
                    continue
        ok = run_one(arch, shape, mp, args.out)["ok"]
        n_ok += ok
        n_fail += not ok
    print(f"sweep done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")


if __name__ == "__main__":
    main()
