"""Production mesh construction.

The production target is a TPU v5e pod slice: 256 chips arranged (16, 16)
with logical axes ("data", "model"); the multi-pod configuration prepends a
"pod" axis of size 2 (512 chips).  Axis roles:

  pod    pure data parallelism across pods (DCN); cross-pod gradient
         reduction optionally compressed (repro.optim.grad_compress).
  data   sample parallelism (paper's N dimension) + FSDP weight sharding.
  model  the paper's fine-grained axis: spatial (H) for CNNs, sequence for
         transformers/SSMs; beyond-paper channel/filter (TP/EP) parallelism
         lives on the same axis, selectable per layer (core.strategy).

Defined as functions (never module-level constants) so importing this module
does not touch jax device state.
"""
from __future__ import annotations

import jax

DATA_AXES = ("pod", "data")     # axes that shard the sample (N) dimension
MODEL_AXIS = "model"            # the paper's fine-grained axis


def _mk(shape, axes, devices=None):
    kw = {"devices": devices} if devices is not None else {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:       # pre-AxisType jax: Auto is the only behavior
        return jax.make_mesh(shape, axes, **kw)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(data: int = 1, model: int = 1, pod: int = 1, devices=None):
    """Small/elastic mesh for tests, examples and CPU runs.

    Always uses the same axis names as production so every sharding rule and
    shard_map island is identical from 1 chip to 512 — this is the elastic-
    scaling contract: checkpoints are mesh-independent (global shapes) and any
    (pod, data, model) factorization of the available devices works.

    `devices` restricts the mesh to an explicit device list — how an
    elastic restart rebuilds over the *survivors* of a device loss (and
    how tests carve a 4-device mesh out of an 8-device backend).
    """
    ndev = len(devices) if devices is not None else jax.device_count()
    if pod * data * model > ndev:
        raise ValueError(f"mesh {(pod, data, model)} needs {pod*data*model} "
                         f"devices, have {ndev}")
    if devices is not None:
        devices = list(devices)[:pod * data * model]
    if pod > 1:
        return _mk((pod, data, model), ("pod", "data", "model"), devices)
    return _mk((data, model), ("data", "model"), devices)


def elastic_factorization(n: int, *, batch: int | None = None
                          ) -> tuple[int, int]:
    """A (data, model) factorization of `n` surviving devices.

    Prefers the most balanced split whose data size divides the global
    batch (sample parallelism needs N % data == 0); when nothing divides —
    e.g. 3 survivors with batch 4 — everything lands on the model axis,
    where the paper's fine-grained spatial/CF parallelism needs no batch
    divisibility at all.  This is what makes a 4->3 shrink solvable.
    """
    best = 1
    for data in range(1, int(n ** 0.5) + 1):
        if n % data == 0 and (batch is None or batch % data == 0):
            best = data
    return best, n // best


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get(MODEL_AXIS, 1)
