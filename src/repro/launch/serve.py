"""Batched serving driver: prefill the prompt batch, then greedy-decode with
the sequence-sharded KV cache (the paper's decomposition applied to
inference).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--data 2 --model 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch import shardings as SH
from repro.launch.mesh import make_mesh, batch_axes
from repro.models.lm import transformer as T
from repro.models.lm.modules import ShardCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.canon(args.arch)
    cfg = registry.get(arch, smoke=args.smoke)
    mesh = make_mesh(data=args.data, model=args.model)
    ba = batch_axes(mesh)
    sharded = args.model > 1
    ctx = ShardCtx(mesh=mesh, seq_axis="model" if sharded else None,
                   batch_axes=ba if args.data > 1 else ())

    params = T.init(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    # pad cache length to a multiple of the sequence shards
    m = dict(mesh.shape).get("model", 1)
    max_len = ((max_len + m - 1) // m) * m

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    tokens = jnp.asarray(prompts)
    frames = None
    if cfg.frontend == "audio_stub":
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len,
                                    cfg.d_model))

    t0 = time.time()
    with mesh:
        memory = None
        if cfg.is_encdec:
            memory = T.encode(params, cfg, frames, ctx, remat=False)
        caches = T.init_decode_state(params, cfg, args.batch, max_len,
                                     dtype=jnp.float32)
        if sharded:
            cspecs = SH.kv_cache_specs(caches, mesh, args.data > 1, "model")
            caches = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                caches, cspecs)
        decode = jax.jit(
            lambda p, t, c, L, mem: T.decode_step(p, cfg, t, c, L, ctx,
                                                  memory=mem),
            donate_argnums=(2,))
        # teacher-forced prefill via the decode path (prompt replay), then
        # greedy generation.  (Bulk ring-attention prefill: T.prefill.)
        out = []
        tok = tokens[:, :1]
        for i in range(args.prompt_len + args.gen - 1):
            logits, caches = decode(params, tok, caches, jnp.int32(i),
                                    memory)
            if i + 1 < args.prompt_len:
                tok = tokens[:, i + 1:i + 2]
            else:
                tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
                out.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    gen = np.stack(out, 1)
    steps = args.prompt_len + args.gen - 1
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"{steps} decode steps in {dt:.1f}s "
          f"({dt/steps*1e3:.1f} ms/step incl. compile)")
    print("generated token ids:\n", gen)
    assert np.isfinite(gen).all()


if __name__ == "__main__":
    main()
