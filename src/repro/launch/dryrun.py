"""Multi-pod dry-run: AOT-lower + compile every (arch x shape) cell on the
production mesh, proving the distribution config is coherent, that it fits
HBM (memory_analysis) and extracting roofline terms (cost_analysis +
collective bytes parsed from the compiled HLO).

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k [--multi-pod] [--variant opt] [--out DIR]

Writes one JSON artifact per cell to benchmarks/artifacts/dryrun/.

With --audit this becomes the static-analysis lane's driver instead: no
arch cell, no 512-device pod — the bench workloads' plans are solved
(repro.analysis.workloads, the same registry strategy_exec times) on a
small host mesh and each is linted + collective-audited lowering-only
(NetworkPlan.audit: jaxpr + StableHLO vs the priced inventory).  Findings
print as a table and land in one JSON artifact; any error-severity
finding exits non-zero.  Not a single timed step runs.

  PYTHONPATH=src python -m repro.launch.dryrun --audit \
      [mesh16cf mesh16_proxy ...] [--audit-out FILE]
"""
import os
import sys

# device count MUST precede every other import (jax locks it on first
# init): the pod-scale lowering wants 512 host devices, the --audit lane
# wants the small bench mesh (2x2, matching the CI bench lane).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4"
    if "--audit" in sys.argv else
    "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh, batch_axes
from repro.models.lm import transformer as T
from repro.models.lm.modules import ShardCtx
from repro.optim.optimizer import adamw
from repro.utils import BF16

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e per assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# per-cell step builders
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape: str, mesh, variant: str = "base",
                  cfg=None, unroll: bool = False):
    cfg = cfg or registry.get(arch)
    info = registry.SHAPES[shape]
    seq, gbatch, kind = info["seq_len"], info["global_batch"], info["kind"]
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= dict(mesh.shape)[a]
    bspec = ba if gbatch % max(nb, 1) == 0 and gbatch >= nb else None
    seq_axes = "model" if bspec is not None else ("data", "model")

    ctx = ShardCtx(mesh=mesh, seq_axis=seq_axes if kind != "train"
                   else "model",
                   batch_axes=(ba if bspec is not None else ()),
                   unroll=unroll)
    # training always has batch >= devices in the assigned cells
    if kind == "train":
        assert bspec is not None
        ctx = ShardCtx(mesh=mesh, seq_axis="model", batch_axes=ba,
                       unroll=unroll,
                       tp_axis="model" if variant == "opt" and
                       cfg.n_experts else None)

    # parameters (abstract init — no allocation).  Non-EP MoE (mixtral's
    # 8 experts < 16 shards) in the opt variant keeps bf16 master weights
    # (fp32 Adam moments remain) — the remaining lever that fits 46.7B
    # params after ZeRO-1 (§Perf).
    big_moe = cfg.n_experts and cfg.n_experts % dict(mesh.shape)["model"]
    p_dtype = jnp.bfloat16 if kind != "train" or \
        (variant in ("opt", "vpz") and big_moe) else jnp.float32
    p_abs = jax.eval_shape(lambda k: T.init(k, cfg, dtype=p_dtype),
                           jax.random.PRNGKey(0))
    pspecs = SH.fsdp_tree_specs(p_abs, mesh)
    m_sz = dict(mesh.shape)["model"]
    if variant in ("opt", "vpz"):
        # hillclimbed sharding (EXPERIMENTS.md §Perf): vocab-parallel
        # embedding (V over the model axis, when divisible — otherwise the
        # in-loss pad/reshard handles it) + expert parallelism for MoE
        # (E over the model axis — the paper's §III-D filter parallelism).
        pspecs = dict(pspecs)
        if cfg.vocab % m_sz == 0:
            pspecs["embed"] = P("model", None)
            if "unembed" in pspecs:
                pspecs["unembed"] = P(None, "model")
        if variant == "opt" and cfg.n_experts and \
                cfg.n_experts % m_sz == 0:
            def ep_spec(leaf_spec, leaf):
                if leaf.ndim >= 4 and leaf.shape[1] == cfg.n_experts:
                    rest = [None] * (leaf.ndim - 2)
                    for d in range(2, leaf.ndim):
                        if leaf.shape[d] % dict(mesh.shape)["data"] == 0:
                            rest[d - 2] = "data"
                            break
                    return P(None, "model", *rest)
                return leaf_spec
            pspecs["segments"] = jax.tree.map(
                ep_spec, pspecs["segments"], p_abs["segments"])
    params = SH.with_sharding(p_abs, mesh, pspecs)

    extra: dict[str, Any] = {}
    text_len = seq
    if cfg.frontend == "vit_stub" and kind != "decode":
        fl = min(cfg.frontend_len, seq // 2)
        text_len = seq - fl
        extra["patch_embeds"] = sds((gbatch, fl, cfg.d_model), jnp.bfloat16,
                                    mesh, P(bspec, "model", None))
    if cfg.frontend == "audio_stub":
        enc_len = seq if kind != "decode" else min(seq, 4096)
        extra["frames"] = sds((gbatch, enc_len, cfg.d_model), jnp.bfloat16,
                              mesh, P(bspec, "model", None))

    if kind == "train":
        opt = adamw(3e-4)
        opt_state = jax.eval_shape(opt.init, p_abs)
        # optimizer state: inherits param shardings (baseline) or ZeRO-1
        # over all chips (opt variant — EXPERIMENTS.md §Perf)
        ospecs = SH.zero1_tree_specs(p_abs, mesh) \
            if variant in ("opt", "vpz") else pspecs
        from repro.optim.optimizer import OptState
        opt_sds = OptState(
            sds((), jnp.int32, mesh, P()),
            SH.with_sharding(opt_state.mu, mesh, ospecs),
            SH.with_sharding(opt_state.nu, mesh, ospecs)
            if opt_state.nu is not None else None)

        batch = {"tokens": sds((gbatch, text_len), jnp.int32, mesh,
                               P(bspec, "model")),
                 "labels": sds((gbatch, text_len), jnp.int32, mesh,
                               P(bspec, "model"))}
        batch.update(extra)

        def loss(p, b):
            return T.loss_fn(p, b, cfg, ctx, remat=True, unroll=unroll,
                             vocab_parallel=variant in ("opt", "vpz"))

        from repro.train.train_loop import make_train_step, TrainStepConfig
        # micro-batching (the paper's memory lever [43]) for the non-EP
        # MoE opt variant: halves activation residency per micro-step.
        ga = 2 if (variant in ("opt", "vpz") and big_moe) else 1
        step = make_train_step(loss, opt, mesh,
                               TrainStepConfig(precision=BF16, remat=False,
                                               grad_accum=ga))
        args = (params, opt_sds, None, batch)
        return step, args, cfg

    if kind == "prefill":
        batch = {"tokens": sds((gbatch, text_len), jnp.int32, mesh,
                               P(bspec, "model"))}
        batch.update(extra)

        def prefill_fn(p, b):
            return T.prefill(p, cfg, b["tokens"], ctx,
                             extra_embeds=b.get("patch_embeds"),
                             frames=b.get("frames"), unroll=unroll)
        return jax.jit(prefill_fn), (params, batch), cfg

    # decode
    cache_abs = jax.eval_shape(
        lambda: T.init_decode_state(None, cfg, gbatch, seq, jnp.bfloat16))
    cspecs = SH.kv_cache_specs(cache_abs, mesh, bspec is not None, seq_axes)
    caches = SH.with_sharding(cache_abs, mesh, cspecs)
    tokens = sds((gbatch, 1), jnp.int32, mesh, P(bspec, None))
    length = sds((), jnp.int32, mesh, P())
    mem = None
    if cfg.is_encdec:
        mem = sds((gbatch, min(seq, 4096), cfg.d_model), jnp.bfloat16, mesh,
                  P(bspec, seq_axes if seq >= 8192 else "model", None))

    def decode_fn(p, t, c, L, m):
        return T.decode_step(p, cfg, t, c, L, ctx, memory=m, unroll=unroll)

    # donate the cache: decode updates it in place (aliased buffers)
    return (jax.jit(decode_fn, donate_argnums=(2,)),
            (params, tokens, caches, length, mem), cfg)


def build_cnn_cell(arch: str, mesh, batch: int = 32, variant: str = "base"):
    """Bonus cells: the paper's own CNN workloads under hybrid parallelism.

    variant="opt": bf16 activations/compute (fp32 master + BN stats) — the
    v5e-native precision the fp32-trained paper never used."""
    from repro.configs import registry as R
    import functools
    from repro.core.spatial_conv import ConvSharding
    from repro.optim.optimizer import sgd
    from repro.train.train_loop import make_train_step, TrainStepConfig
    from repro.utils import BF16, FP32
    cfg = R.get(arch)
    ba = batch_axes(mesh)
    sh = ConvSharding(batch_axes=ba, h_axis="model")
    if arch == "resnet50":
        from repro.models.cnn import resnet as M
        x = sds((batch, cfg.input_hw, cfg.input_hw, cfg.in_channels),
                jnp.float32, mesh, P(ba, "model", None, None))
        y = sds((batch,), jnp.int32, mesh, P(ba))
        loss = functools.partial(M.loss_fn, cfg=cfg, plan=sh, mesh=mesh)
        bdict = {"image": x, "label": y}
    else:
        from repro.models.cnn import meshnet as M
        x = sds((batch, cfg.input_hw, cfg.input_hw, cfg.in_channels),
                jnp.float32, mesh, P(ba, "model", None, None))
        y = sds((batch, cfg.out_hw, cfg.out_hw, 1), jnp.float32,
                mesh, P(ba, None, None, None))
        loss = functools.partial(M.loss_fn, cfg=cfg, plan=sh, mesh=mesh)
        bdict = {"image": x, "label": y}
    p_abs = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    pspecs = SH.fsdp_tree_specs(p_abs, mesh)
    params = SH.with_sharding(p_abs, mesh, pspecs)
    opt = sgd(0.1, momentum=0.9)
    opt_state = jax.eval_shape(opt.init, p_abs)
    from repro.optim.optimizer import OptState
    opt_sds = OptState(sds((), jnp.int32, mesh, P()),
                       SH.with_sharding(opt_state.mu, mesh, pspecs), None)
    prec = BF16 if variant == "opt" else FP32
    step = make_train_step(lambda p, b: loss(p, b), opt, mesh,
                           TrainStepConfig(precision=prec))
    return step, (params, opt_sds, None, bdict), cfg


# ---------------------------------------------------------------------------
# HLO collective analysis
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|f64|s64|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
          "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    These are *per-device* shard shapes in SPMD modules, i.e. bytes each
    device injects into the fabric per op instance.
    """
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo.splitlines():
        s = line.strip()
        if "fusion" in s.split("=")[0]:
            continue
        for kind in COLLECTIVES:
            # match op name: `%all-gather.N = shape all-gather(...)`
            if f" {kind}(" in s or f" {kind}-start(" in s:
                lhs = s.split("=")[1] if "=" in s else s
                out[kind] += _shape_bytes(lhs.split(f" {kind}")[0])
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def _measure(fn, args, mesh):
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(sum(v for k, v in coll.items() if k != "count")),
            "colls": coll,
            "compiled": compiled}


def _probe_extrapolate(arch, shape, mesh, variant, n_layers):
    """XLA cost analysis counts while-loop (scan) bodies ONCE, so the full
    lowering under-reports per-layer work.  Probe the same cell at depth 2
    and 4 with the layer scans *unrolled* (loop-free HLO) and extrapolate
    linearly:  total(L) = C2 + (C4 - C2)/2 * (L - 2).  The marginal slope
    is exactly one layer's flops/bytes/collective traffic (incl. its FSDP
    gathers and optimizer update); the intercept holds embed/logits/loss."""
    import dataclasses
    cfg0 = registry.get(arch)
    out = {}
    for d in (2, 4):
        kw = {"n_layers": d}
        if cfg0.is_encdec:
            kw["n_enc_layers"] = d
        cfg_d = dataclasses.replace(cfg0, **kw)
        fn, args, _ = build_lm_cell(arch, shape, mesh, variant, cfg=cfg_d,
                                    unroll=True)
        m = _measure(fn, args, mesh)
        m.pop("compiled")
        out[d] = m
    ex = {}
    for k in ("flops", "bytes", "coll"):
        # clamp: XLA may pick different collective strategies at different
        # depths; a negative marginal is an artifact, not a saving.
        slope = max(0.0, (out[4][k] - out[2][k]) / 2.0)
        ex[k] = out[2][k] + slope * (n_layers - 2)
    ex["probe"] = {2: {k: out[2][k] for k in ("flops", "bytes", "coll")},
                   4: {k: out[4][k] for k in ("flops", "bytes", "coll")}}
    return ex


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             variant: str = "base") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = int(np.prod(list(dict(mesh.shape).values())))
    if arch in registry.CNN_ARCHS:
        fn, args, cfg = build_cnn_cell(arch, mesh, variant=variant)
    else:
        fn, args, cfg = build_lm_cell(arch, shape, mesh, variant)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(sum(v for k, v in coll.items() if k != "count"))
    raw = {"flops": flops_dev, "bytes": bytes_dev, "coll": coll_dev}
    probe = None
    if arch not in registry.CNN_ARCHS:
        with mesh:
            probe = _probe_extrapolate(arch, shape, mesh, variant,
                                       cfg.n_layers)
        flops_dev = probe["flops"]
        bytes_dev = probe["bytes"]
        coll_dev = probe["coll"]

    result = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": nchips,
        "ok": True,
        "per_device": {
            "flops": flops_dev,
            "hbm_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collectives": coll,
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0),
        },
        "roofline_s": {
            "compute": flops_dev / PEAK_FLOPS,
            "memory": bytes_dev / HBM_BW,
            "collective": coll_dev / ICI_BW,
        },
        "timing": {"lower_s": round(t_lower, 1),
                   "compile_s": round(t_compile, 1)},
        "raw_scan_counted_once": raw,
        "probe": probe["probe"] if probe else None,
    }
    dom = max(result["roofline_s"], key=result["roofline_s"].get)
    result["dominant"] = dom
    if arch not in registry.CNN_ARCHS:
        info = registry.SHAPES[shape]
        n_act = cfg.params_per_token()
        toks = info["seq_len"] * info["global_batch"] if \
            info["kind"] != "decode" else info["global_batch"]
        mf = 6.0 * n_act * toks if info["kind"] == "train" \
            else 2.0 * n_act * toks
        result["model_flops_total"] = mf
        result["model_flops_ratio"] = mf / max(flops_dev * nchips, 1.0)

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}-{shape}-{'pod2' if multi_pod else 'pod1'}"
    if variant != "base":
        tag += f"-{variant}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_audit(workload_names, out_path: str, hlo: bool = True,
              search: str = "greedy") -> int:
    """--audit: lint + collective-audit the bench workloads' solved plans,
    lowering-only.  `search` selects the solver's search mode (greedy |
    beam[:N] | hillclimb) so the widened-search plans audit costed ==
    executed too.  Returns the process exit code (non-zero iff any
    error-severity finding)."""
    from repro import analysis
    from repro.analysis import workloads as WL
    from repro.core import perfmodel as pm
    from repro.launch.mesh import make_mesh
    from repro.utils import replication_policy

    ndev = jax.device_count()
    data = max(1, ndev // 2)
    model = max(1, ndev // data)
    mesh = make_mesh(data=data, model=model)
    names = list(workload_names) or list(WL.WORKLOADS)
    report = {
        "schema": "repro/plan_audit@1",
        "backend": jax.default_backend(),
        "mesh": dict(mesh.shape),
        "search": search,
        # which shard_map replication policy each backend's regions
        # compile under (the one utils.replication_policy source of truth)
        "replication_policy": {
            b: {**dataclasses.asdict(replication_policy(b)),
                "legacy_check_rep": replication_policy(b).legacy_check_rep}
            for b in ("xla", "pallas")},
        "workloads": {},
    }
    n_errors = 0
    for name in names:
        w = WL.WORKLOADS[name]
        if w.needs_model_axis and model <= 1:
            print(f"# audit/{name}: SKIPPED (mesh has no model axis)")
            report["workloads"][name] = {"skipped": True}
            continue
        t0 = time.time()
        plan, specs, cfg = WL.solve_workload(name, pm.TPU_V5E, mesh,
                                             search=search)
        findings = plan.audit(specs, mesh, cfg=cfg, overlap=True, hlo=hlo)
        errs = analysis.error_count(findings)
        n_errors += errs
        print(f"# audit/{name}: {len(findings)} finding(s), {errs} "
              f"error(s) ({time.time() - t0:.1f}s lowering-only)")
        print(analysis.format_findings(findings))
        report["workloads"][name] = {
            "skipped": False,
            "n_findings": len(findings),
            "n_errors": errs,
            "n_reshards": plan.n_reshards,
            "findings": [f.to_json() for f in findings],
        }
    report["n_errors"] = n_errors
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {out_path}")
    if n_errors:
        print(f"# AUDIT FAILED: {n_errors} error-severity finding(s) — "
              f"costed != executed")
    return 1 if n_errors else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", default="train_4k",
                    choices=list(registry.SHAPES) + ["cnn"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--audit", nargs="*", default=None,
                    metavar="WORKLOAD",
                    help="static-analysis mode: lint + collective-audit "
                         "the named bench workload plans (all when none "
                         "named) instead of lowering an arch cell; exits "
                         "non-zero on any error-severity finding")
    ap.add_argument("--audit-out",
                    default="benchmarks/artifacts/audit/PLAN_audit.json")
    ap.add_argument("--no-hlo", action="store_true",
                    help="with --audit: skip the StableHLO cross-check "
                         "pass (jaxpr-only, faster)")
    ap.add_argument("--search", default="greedy",
                    metavar="greedy|beam[:N]|hillclimb",
                    help="with --audit: solver search mode for the audited "
                         "workload plans — CI audits the widened beam "
                         "search's plans next to the greedy ones")
    args = ap.parse_args()
    if args.audit is not None:
        raise SystemExit(run_audit(args.audit, args.audit_out,
                                   hlo=not args.no_hlo,
                                   search=args.search))
    if not args.arch:
        ap.error("--arch is required (unless running --audit)")
    r = run_cell(registry.canon(args.arch), args.shape, args.multi_pod,
                 args.out, args.variant)
    rl = r["roofline_s"]
    print(f"{args.arch} {args.shape} {r['mesh']}: OK "
          f"compute={rl['compute']*1e3:.2f}ms memory={rl['memory']*1e3:.2f}ms "
          f"collective={rl['collective']*1e3:.2f}ms dominant={r['dominant']} "
          f"peak={r['per_device']['peak_bytes']/2**30:.2f}GiB/dev "
          f"(lower {r['timing']['lower_s']}s compile {r['timing']['compile_s']}s)")


if __name__ == "__main__":
    main()
