"""Performance model for parallel CNN/transformer training (paper §V).

Structure mirrors the paper exactly:

  * compute: C(n,c,h,w,f), Cw(...), Cx(...) — per-layer local runtimes.  The
    paper times cuDNN empirically; we use an analytic FLOP/byte roofline with
    a calibratable efficiency term, plus an `EmpiricalTable` hook so measured
    timings (the paper's method) can be dropped in when hardware is at hand.
  * communication: linear α-β model (§II-B); collectives per Thakur et al. —
    the allreduce picks the min over ring / recursive-doubling / Rabenseifner
    exactly like MPICH's size-based algorithm selection.
  * layer cost (§V-A):  Cost_D(ℓ) = FP + BPx + BPw + BPa, with halo SR terms
    when H/W are partitioned and overlap adjustments (§IV-A).
  * network cost (§V-B): Σ layer costs + Shuffle(D_i, D_j) redistribution on
    distribution changes + greedy one-at-a-time allreduce/backprop overlap.

Units: seconds, bytes, FLOPs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.distribution import Dist
from repro.utils import cdiv, human_bytes, same_pads


# ---------------------------------------------------------------------------
# machines
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    peak_flops: float          # per device, training dtype
    mem_bw: float              # HBM bytes/s
    alpha: float               # p2p latency, s (halo-scale messages)
    beta: float                # p2p inverse bandwidth, s/byte (per link)
    alpha_coll: float          # latency for collective steps
    beta_coll: float           # inverse bandwidth on the allreduce fabric
    wordsize: int = 4
    # fraction of peak a well-shaped conv/matmul reaches; the calibration
    # hook (EmpiricalTable / calibrate_efficiency) can override per layer.
    compute_efficiency: float = 0.55
    # half-performance work (FLOPs): achieved efficiency for a kernel with
    # local work `fl` is eff·fl/(fl + eff_halfwork) — the empirical
    # small-kernel saturation the paper captures by measuring cuDNN
    # directly ("local convolution kernels not scaling linearly", §VI-B1).
    eff_halfwork: float = 0.0
    # per-device memory capacity in bytes (0 = unknown/unlimited).  The
    # planning layers treat this as the §VI Table-2 forcing function:
    # sample parallelism cannot reduce per-device activations below one
    # sample, so large-sample workloads are *unreachable* without the
    # spatial/hybrid decompositions a capacity-constrained solve picks.
    mem_capacity: float = 0.0
    # achieved-overlap efficiency η ∈ [0, 1] (§IV-A latency hiding): the
    # fraction of min(comm, compute) the interior/boundary schedule really
    # hides on this machine, fitted by core.calibrate from interleaved
    # overlapped-vs-serialized microbenchmarks.  The analytic default 1.0
    # reproduces the paper's full credit max(comm, compute); η = 0 degrades
    # to fully serialized, so the solver is never rewarded for overlap the
    # hardware cannot deliver.
    overlap_eta: float = 1.0
    # composition correction factors, fitted by core.calibrate from fused
    # microbenchmarks (the 4–13× model/measured gap on the composed
    # workloads lives in exactly these terms).  All default to 1.0 (pure
    # analytic model).  They scale priced *seconds* only, never payload
    # bytes, so the static collective auditor is unaffected.
    #   composed_cf_factor: CF data collectives executing inside a halo'd
    #     spatial block (CF × spatial shard_maps) vs the standalone α-β fit.
    #   composed_halo_factor: product-axis halo exchange with its
    #     boundary-crossing hops vs the single-axis p2p fit.
    #   shuffle_factor: §III-C all-to-all reshard vs the analytic pairwise
    #     model, used when no measured `shuffle:` table entry is near.
    composed_cf_factor: float = 1.0
    composed_halo_factor: float = 1.0
    shuffle_factor: float = 1.0


# Lassen (paper's machine): V100 fp32 ~15.7 TF; NVLINK2 ~150 GB/s/dir
# on-node, dual-rail EDR IB ~ 2x12.5 GB/s across nodes.  Halo exchanges in
# the paper's large runs cross nodes (8/16-way spatial), so p2p constants
# use the IB path; allreduces are NCCL ring across everything (IB-bound).
LASSEN = Machine("lassen-v100", peak_flops=15.7e12, mem_bw=900e9,
                 alpha=4.0e-6, beta=1 / 21.0e9,
                 alpha_coll=6.0e-6, beta_coll=1 / 21.0e9, wordsize=4,
                 compute_efficiency=0.50, mem_capacity=16e9)

# TPU v5e (the build target): constants given by the assignment.
TPU_V5E = Machine("tpu-v5e", peak_flops=197e12, mem_bw=819e9,
                  alpha=1.0e-6, beta=1 / 50.0e9,
                  alpha_coll=1.0e-6, beta_coll=1 / 50.0e9, wordsize=2,
                  compute_efficiency=0.55, mem_capacity=16e9)


# ---------------------------------------------------------------------------
# communication (paper §II-B; Thakur et al. collectives)
# ---------------------------------------------------------------------------

def sr_time(m: Machine, nbytes: float, hops: int = 1) -> float:
    """SR(n): send+receive n bytes between two processors (full duplex).

    `hops`: link hops the message traverses.  1 for torus neighbors; a
    spatial dim split over a *product* of mesh axes (core.halo) pays more —
    the boundary-crossing sends of the linearized neighbor pattern travel
    across the outer torus dimension — so callers pass the number of axes
    in the product.  Latency scales with hops; bandwidth stays per-link
    (wormhole routing)."""
    if nbytes <= 0:
        return 0.0
    return max(hops, 1) * m.alpha + m.beta * nbytes


def allreduce_time(m: Machine, p: int, nbytes: float) -> float:
    """AR(p, n): MPICH-style min over candidate algorithms (Thakur et al.)."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    lg = math.log2(p)
    ring = 2 * (p - 1) * m.alpha_coll + 2 * (p - 1) / p * nbytes * m.beta_coll
    rec_dbl = math.ceil(lg) * (m.alpha_coll + nbytes * m.beta_coll)
    rabens = 2 * math.ceil(lg) * m.alpha_coll \
        + 2 * (p - 1) / p * nbytes * m.beta_coll
    return min(ring, rec_dbl, rabens)


def reduce_scatter_time(m: Machine, p: int, nbytes: float) -> float:
    if p <= 1 or nbytes <= 0:
        return 0.0
    return (p - 1) * m.alpha_coll + (p - 1) / p * nbytes * m.beta_coll


def all_gather_time(m: Machine, p: int, nbytes: float) -> float:
    return reduce_scatter_time(m, p, nbytes)


def all_to_all_time(m: Machine, p: int, nbytes_local: float) -> float:
    """Each processor exchanges its local block with everyone (pairwise)."""
    if p <= 1 or nbytes_local <= 0:
        return 0.0
    return (p - 1) * m.alpha + (p - 1) / p * nbytes_local * m.beta


# ---------------------------------------------------------------------------
# layer specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One conv (or conv-like) layer: N samples, C->F channels, HxW, KxK/S."""
    name: str
    n: int; c: int; h: int; w: int; f: int
    k: int = 3
    s: int = 1
    kind: str = "conv"           # conv | pool | fc(=1x1 on 1x1) | bn ...

    @property
    def h_out(self) -> int: return cdiv(self.h, self.s)
    @property
    def w_out(self) -> int: return cdiv(self.w, self.s)
    @property
    def o(self) -> int: return self.k // 2

    def flops_fwd(self) -> float:
        if self.kind == "pool":
            return self.n * self.f * self.h_out * self.w_out * self.k ** 2
        return 2.0 * self.n * self.c * self.h_out * self.w_out \
            * self.k ** 2 * self.f

    def weight_words(self) -> float:
        return 0.0 if self.kind == "pool" else self.k ** 2 * self.c * self.f

    def act_words(self) -> float:          # output activation size
        return self.n * self.f * self.h_out * self.w_out


# key families beyond the conv-shape 8-tuples: measured §III-C reshard
# shuffles keyed (SHUFFLE_KIND, p_total, local_bytes) — one direction's
# seconds; shuffle_time charges 2×.  Composed-microbench provenance rows
# use the "composed:" prefix (calibrate writes them; lookup ignores them).
SHUFFLE_KIND = "shuffle:a2a"


class EmpiricalTable:
    """Optional measured-runtime lookup, the paper's own methodology: keys
    (kind, n, c, h, w, f, k, s) -> seconds.  Falls back to the analytic
    model for missing entries.  `core.calibrate` fills it by timing local
    convolutions at the shard shapes the solver's candidates produce, and
    round-trips it through JSON (BENCH_calibration.json).  Also holds the
    measured `shuffle:`/`composed:` key families (see SHUFFLE_KIND)."""

    def __init__(self, entries: Mapping[tuple, float] | None = None):
        self.entries = dict(entries or {})

    def lookup(self, layer: ConvLayer, n, c, h, w, f) -> float | None:
        return self.entries.get((layer.kind, n, c, h, w, f, layer.k, layer.s))

    def lookup_shuffle(self, p: int, nbytes: int) -> float | None:
        """Measured one-direction shuffle seconds at group size `p` and
        `nbytes` local bytes: exact hit, else piecewise-linear interpolation
        between the nearest measured sizes at the same p (clamped to the
        endpoints outside the measured range)."""
        t = self.entries.get((SHUFFLE_KIND, p, nbytes))
        if t is not None:
            return t
        rows = sorted((k[2], v) for k, v in self.entries.items()
                      if k[0] == SHUFFLE_KIND and k[1] == p)
        if not rows:
            return None
        # outside 2× of the measured range the table says nothing — fall
        # back to the analytic model (× shuffle_factor) rather than clamp.
        if nbytes < rows[0][0] // 2 or nbytes > 2 * rows[-1][0]:
            return None
        if nbytes <= rows[0][0]:
            return rows[0][1]
        if nbytes >= rows[-1][0]:
            return rows[-1][1]
        for (b0, t0), (b1, t1) in zip(rows, rows[1:]):
            if b0 <= nbytes <= b1:
                frac = (nbytes - b0) / max(b1 - b0, 1)
                return t0 + frac * (t1 - t0)
        return None

    def __len__(self) -> int:
        return len(self.entries)

    def __eq__(self, other) -> bool:
        return isinstance(other, EmpiricalTable) and \
            self.entries == other.entries

    def to_json(self) -> list:
        """JSON-serializable form: sorted [[kind, n, c, h, w, f, k, s], t]
        rows (tuple keys cannot be JSON object keys)."""
        return [[list(k), v] for k, v in sorted(self.entries.items())]

    @classmethod
    def from_json(cls, rows: Sequence) -> "EmpiricalTable":
        return cls({(str(k[0]), *(int(v) for v in k[1:])): float(t)
                    for k, t in rows})


# fixed kernel-launch overhead added to every conv roofline estimate; the
# calibrator (core.calibrate) subtracts it before attributing the linear-fit
# intercept to eff_halfwork, so the two must stay one constant.
LAUNCH_OVERHEAD = 4e-6


def conv_compute_time(m: Machine, layer: ConvLayer, n, c, h, w, f,
                      table: EmpiricalTable | None = None,
                      eff: float | None = None) -> float:
    """C(n,c,h,w,f): local forward runtime on the per-processor shard."""
    if table is not None:
        t = table.lookup(layer, n, c, h, w, f)
        if t is not None:
            return t
    if n <= 0 or h <= 0 or w <= 0:
        return 0.0
    h_out, w_out = cdiv(h, layer.s), cdiv(w, layer.s)
    if layer.kind == "pool":
        flops = n * f * h_out * w_out * layer.k ** 2
        byts = (n * c * h * w + n * f * h_out * w_out) * m.wordsize
        return max(flops / (0.05 * m.peak_flops), byts / m.mem_bw) + 2e-6
    flops = 2.0 * n * c * h_out * w_out * layer.k ** 2 * f
    byts = (n * c * h * w + n * f * h_out * w_out
            + layer.k ** 2 * c * f) * m.wordsize
    e = eff if eff is not None else m.compute_efficiency
    if m.eff_halfwork > 0:
        e = e * flops / (flops + m.eff_halfwork)
    # roofline max(compute, memory) + a fixed kernel-launch overhead; the
    # launch overhead is what caps strong scaling of tiny local convs
    # (paper Fig. 2, res3b fwd) — without it the model is wildly optimistic.
    return max(flops / (e * m.peak_flops), byts / m.mem_bw) + LAUNCH_OVERHEAD


# ---------------------------------------------------------------------------
# layer cost under a distribution (paper §V-A)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerCost:
    fp: float = 0.0
    bpx: float = 0.0
    bpw: float = 0.0
    bpa: float = 0.0          # dL/dw allreduce (overlappable, §V-B)
    fp_compute: float = 0.0   # components, for the overlap simulation
    bp_compute: float = 0.0
    fp_saved: float = 0.0     # η·min(comm, compute) credited in FP
    bp_saved: float = 0.0     # η·min(halo_dy, BPw compute) credited in BP

    @property
    def overlap_credit(self) -> float:
        """Seconds of communication the §IV-A schedule is credited with
        hiding, already scaled by the machine's achieved η — what
        plan.describe() reports per layer."""
        return self.fp_saved + self.bp_saved

    @property
    def total(self) -> float:
        return self.fp + self.bpx + self.bpw + self.bpa


def _halo_time(m: Machine, o: int, n_l: int, c_l: int, h_l: int, w_l: int,
               h_hops: int, w_hops: int) -> float:
    """2 SR(O·n·c·w) + 2 SR(O·n·c·h) + 4 SR(O²·n·c) as applicable (§V-A).

    `h_hops`/`w_hops`: 0 when the dim is unsplit; else the number of mesh
    axes in its (possibly product) split — product-axis halos pay extra
    link hops on the boundary-crossing sends (see sr_time)."""
    if o == 0:
        return 0.0
    t = 0.0
    ws = m.wordsize
    if h_hops:
        t += 2 * sr_time(m, o * n_l * c_l * w_l * ws, h_hops)
    if w_hops:
        t += 2 * sr_time(m, o * n_l * c_l * h_l * ws, w_hops)
    if h_hops and w_hops:
        t += 4 * sr_time(m, o * o * n_l * c_l * ws, h_hops + w_hops)
    return t


def layer_cost(m: Machine, layer: ConvLayer, dist: Dist,
               mesh_shape: Mapping[str, int],
               table: EmpiricalTable | None = None,
               overlap: bool = True,
               eff: float | None = None) -> LayerCost:
    """Cost_D(ℓ) (§V-A).  `mesh_shape` maps mesh axis -> size."""
    n_l = layer.n // max(dist.ways("N", mesh_shape), 1)
    h_l = layer.h // max(dist.ways("H", mesh_shape), 1)
    w_l = layer.w // max(dist.ways("W", mesh_shape), 1)
    c_l = layer.c // max(dist.ways("C", mesh_shape), 1)
    f_l = layer.f // max(dist.ways("F", mesh_shape), 1)
    # hop counts for the halo terms: the number of mesh axes each spatial
    # dim is split over (0 = unsplit) — a product-axis split's boundary
    # messages cross the outer torus dimension (see sr_time).
    h_hops = len(dist.axes("H")) if dist.ways("H", mesh_shape) > 1 else 0
    w_hops = len(dist.axes("W")) if dist.ways("W", mesh_shape) > 1 else 0

    c = LayerCost()
    # Channel/filter parallelism (§III-D) is costed as the single-axis
    # scheme where x enters C-sharded, each processor contracts its channel
    # block against full-F weight rows, and a reduce-scatter over the group
    # completes the channel sum leaving y F-sharded (the conv analogue of
    # Megatron row-parallel): compute sees (c_l, full f), comm is RS(y).
    # This is exactly what core.channel_conv's 'channel' mode executes
    # (benchmarks/strategy_exec.py cross-checks these terms against its
    # measured step times); its 'filter' mode trades the RS(y) for AG(x).
    p_c = dist.ways("C", mesh_shape)
    p_f = dist.ways("F", mesh_shape)
    h_out_l = layer.h_out // max(dist.ways("H", mesh_shape), 1)
    w_out_l = layer.w_out // max(dist.ways("W", mesh_shape), 1)
    f_fwd = layer.f if p_c > 1 else f_l
    fp_comp = conv_compute_time(m, layer, n_l, c_l, h_l, w_l, f_fwd, table,
                                eff)
    # composition correction factors (fitted by core.calibrate from fused
    # microbenchmarks; 1.0 = pure analytic).  halo_f applies when a spatial
    # dim is split over a *product* of mesh axes (boundary-crossing hops);
    # cf_f applies to the CF collectives when they execute inside a halo'd
    # spatial block (CF × spatial composition).
    halo_f = m.composed_halo_factor if (h_hops > 1 or w_hops > 1) else 1.0
    cf_f = m.composed_cf_factor if (p_c > 1 or p_f > 1) and \
        (h_hops or w_hops) else 1.0
    halo_x = halo_f * _halo_time(m, layer.o, n_l, c_l, h_l, w_l,
                                 h_hops, w_hops)
    if p_c > 1:
        # the CF data collective runs at the *sub-mesh* size p_c with the
        # spatially-local payload (h_out_l/w_out_l already divide out any
        # composed H/W split).  The runtime executes whichever §III-D mode
        # moves fewer words — RS(y) in 'channel' mode vs AG(x) in 'filter'
        # mode (core.plan picks it with cf_mode_for) — so the forward term
        # prices that min and the costed plan matches the executed one.
        words = cf_collective_words(layer, dist, mesh_shape)
        halo_x += cf_f * min(
            reduce_scatter_time(m, p_c, words["rs_y"] * m.wordsize),
            all_gather_time(m, p_c, words["ag_x"] * m.wordsize))
    # overlap credit (§IV-A): the schedule can hide at most min(comm,
    # compute); the machine's measured η says what fraction it actually
    # hides.  η = 1 (analytic default) makes the overlapped cost exactly
    # max(comm, compute); η = 0 makes it comm + compute (serialized).
    eta = min(max(m.overlap_eta, 0.0), 1.0) if overlap else 0.0
    c.fp_compute = fp_comp
    c.fp_saved = eta * min(halo_x, fp_comp)
    c.fp = fp_comp + halo_x - c.fp_saved

    if layer.kind == "pool":
        # backward pool ~ forward pool cost; halo on the error signal.
        c.bpx = fp_comp + halo_x - eta * min(halo_x, fp_comp)
        c.bp_saved = eta * min(halo_x, fp_comp)
        c.bp_compute = fp_comp
        return c

    # BPx: halo on dL/dy (F channels) + data-conv compute; under filter
    # parallelism the sum over f ∈ I_F^(p) (Eq. 3) is completed with a
    # reduce-scatter across the F-group, mirroring the forward.  (The
    # backward CF terms below charge both the x-payload RS and the
    # y-payload AG; each mode actually pays only one of them, so backward
    # is priced as an upper bound across modes.)
    c_bpx = layer.c if p_f > 1 else c_l
    bpx_comp = conv_compute_time(m, layer, n_l, c_bpx, h_l, w_l, f_l, table,
                                 eff)
    # dL/dy lives at the *output* extents (h_out/w_out): for strided layers
    # the backward halo messages are stride-times smaller than the forward
    # ones — using the input extents here over-charged BPx comm.
    halo_dy = halo_f * _halo_time(m, layer.o, n_l, f_l, h_out_l, w_out_l,
                                  h_hops, w_hops)
    if p_f > 1:
        halo_dy += cf_f * reduce_scatter_time(
            m, p_f, n_l * layer.c * h_l * w_l * m.wordsize)
    # BPw: local filter-gradient contraction, needs no halo (§IV-A); under
    # CF parallelism it needs full-F dL/dy — an all-gather over the group.
    bpw_comp = conv_compute_time(m, layer, n_l, c_l, h_l, w_l, f_fwd, table,
                                 eff)
    if p_f > 1:
        bpw_comp += cf_f * all_gather_time(
            m, p_f, n_l * layer.f * h_out_l * w_out_l * m.wordsize)
    if overlap:
        # §IV-A: the dL/dx halo exchange hides inside the dL/dw conv —
        # up to the machine's achieved η of the hideable min.
        c.bp_saved = eta * min(halo_dy, bpw_comp)
        c.bpx = bpx_comp
        c.bpw = bpw_comp + halo_dy - c.bp_saved
    else:
        c.bpx = bpx_comp + halo_dy
        c.bpw = bpw_comp
    c.bp_compute = bpx_comp + bpw_comp

    # BPa: allreduce of dL/dw over processors sharing the same (C, F)
    # indices — all of them when weights are replicated (§V-A).
    p_total = 1
    for ax, sz in mesh_shape.items():
        p_total *= sz
    p_cf = dist.ways("C", mesh_shape) * dist.ways("F", mesh_shape)
    p_ar = p_total // max(p_cf, 1)
    c.bpa = allreduce_time(m, p_ar,
                           f_l * c_l * layer.k ** 2 * m.wordsize)
    return c


def cf_collective_words(layer: ConvLayer, dist: Dist,
                        mesh_shape: Mapping[str, int]) -> dict:
    """Payload sizes (words) of the two §III-D data collectives at the
    local shard shapes: 'filter' mode all-gathers x over the CF group,
    'channel' mode reduce-scatters y.  Both run at the sub-mesh size
    `p_cf`; any composed H/W split divides the spatial extents out.  The
    plan compiler picks the runtime mode with the smaller payload."""
    n_l = layer.n // max(dist.ways("N", mesh_shape), 1)
    h_l = layer.h // max(dist.ways("H", mesh_shape), 1)
    w_l = layer.w // max(dist.ways("W", mesh_shape), 1)
    h_out_l = layer.h_out // max(dist.ways("H", mesh_shape), 1)
    w_out_l = layer.w_out // max(dist.ways("W", mesh_shape), 1)
    return {"ag_x": n_l * layer.c * h_l * w_l,
            "rs_y": n_l * layer.f * h_out_l * w_out_l,
            "p_cf": dist.ways("C", mesh_shape)}


def cf_mode_for(layer: ConvLayer, dist: Dist,
                mesh_shape: Mapping[str, int]) -> str:
    """'filter' when the AG(x) payload is smaller than the RS(y) payload,
    else 'channel' — the per-layer mode rule the solver applies (the
    ROADMAP PR-2 leftover: stop picking CF mode blindly)."""
    words = cf_collective_words(layer, dist, mesh_shape)
    return "filter" if words["ag_x"] < words["rs_y"] else "channel"


# ---------------------------------------------------------------------------
# priced-collective inventory (the costed==executed contract, repro.analysis)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One priced collective of a layer under a distribution — the unit the
    static auditor (repro.analysis.collectives) joins the traced jaxpr's
    collectives against.

    kind:       normalized primitive name: ppermute | psum | reduce_scatter
                | all_gather.
    region:     the trace region the runtime issues it under (descriptive).
    direction:  fwd | bwd.
    count:      number of primitive ops the runtime issues.
    bytes:      TOTAL payload bytes across all `count` ops (sum over the
                ops' input avals — the auditor's byte convention).
    axes:       mesh axes the collective runs over (matched as a set).
    term:       the LayerCost term that prices it: fp | bpx | bpw | bpa,
                or 'none' for comm the model knowingly does not charge.
    visibility: 'jaxpr' when the op appears in the traced program (inside
                a shard_map body); 'gspmd' when the partitioner inserts it
                after lowering (invisible to the static walk — exempt from
                phantom-charge checks).
    charged:    whether layer_cost/network_cost actually prices it.  A
                charged=False + visibility='jaxpr' entry is a *known*
                unpriced collective (reported as a warning, not an error).
    """
    kind: str
    region: str
    direction: str
    count: int
    bytes: float
    axes: tuple
    term: str
    visibility: str = "jaxpr"
    charged: bool = True


def _conv_split_geometry(layer: ConvLayer, dist: Dist,
                         mesh_shape: Mapping[str, int]):
    """(d_loc, do_loc, lo, hi, t_lo, t_hi) of the conv-split spatial dim —
    W when W is split (H is fully exchanged first in the both-split path,
    core.spatial_conv._local_conv), else H.  None when neither is split or
    the kernel needs no halo (same_pads == (0, 0))."""
    h_ways = dist.ways("H", mesh_shape)
    w_ways = dist.ways("W", mesh_shape)
    if h_ways <= 1 and w_ways <= 1:
        return None
    lo, hi = same_pads(layer.k, layer.s)
    if lo == 0 and hi == 0:
        return None
    if w_ways > 1:
        d_loc, do_loc = layer.w // w_ways, layer.w_out // w_ways
    else:
        d_loc, do_loc = layer.h // h_ways, layer.h_out // h_ways
    t_lo = cdiv(lo, layer.s)
    i_hi = cdiv(d_loc + lo - layer.k + 1, layer.s)
    t_hi = do_loc - i_hi
    return d_loc, do_loc, lo, hi, t_lo, t_hi


def interior_split(layer: ConvLayer, dist: Dist,
                   mesh_shape: Mapping[str, int],
                   overlap: bool = True) -> bool:
    """Whether the runtime pins the §IV-A interior/boundary split for this
    layer — i.e. core.spatial_conv issues conv_interior under an
    optimization_barrier pin (one forward + one mirrored backward).  False
    for CF-composed layers (channel_conv serializes its spatial halo), for
    kernels needing no halo, without overlap, and when the boundary tiles
    swallow the whole local output (the serialized fallback)."""
    if not overlap:
        return False
    if dist.ways("C", mesh_shape) > 1 or dist.ways("F", mesh_shape) > 1:
        return False
    g = _conv_split_geometry(layer, dist, mesh_shape)
    if g is None:
        return False
    _, do_loc, _, _, t_lo, t_hi = g
    return t_lo + t_hi < do_loc


def layer_collectives(m: Machine, layer: ConvLayer, dist: Dist,
                      mesh_shape: Mapping[str, int], *,
                      overlap: bool = True, first: bool = False,
                      channel_chunks: int = 1) -> list[CollectiveSpec]:
    """THE priced inventory: every collective the runtime issues for
    `layer` under `dist`, with execution-accurate geometry derived from
    the same distribution `layer_cost` prices — each entry tagged with the
    cost term that charges it (or charged=False for comm the model
    knowingly leaves unpriced).

    Conventions (pinned against the traced jaxpr of the real execution
    paths — tests/dist_checks.py `audit` group):

      * halo ppermutes use SAME-padding amounts (lo, hi) = same_pads(k, s)
        per split dim — stride-2 k=3 sends ONE message, k=1 none; H is
        exchanged first with full local W rows, and when both H and W are
        split the W messages carry H-extended rows (corners ride inside
        them — the model's separate 4·SR(o²) corner term is a pricing
        approximation of the same bytes);
      * backward halos are the exact transposes, identical payloads;
        `first=True` marks a first layer whose input gradient is dead
        (loss wrt params only) — its backward halos are DCE'd away;
      * the spatial dL/dw contraction psums once per conv application:
        1 (serialized / no split) or 1 + (t_lo>0) + (t_hi>0) when the
        interior/boundary split is live, each over the full replicated
        weight shape;
      * CF runs the cf_mode_for min-payload mode: 'channel' reduce-
        scatters y forward / all-gathers local dy backward, 'filter'
        all-gathers x forward / reduce-scatters full-C dx backward; the
        weight-block psum over the non-CF processors is charged by BPa
        only when p_ar > 1, and the slice-VJP's full-weight psum over the
        CF axis is genuinely unpriced (charged=False — the standing
        suspect for the mesh16cf drift);
      * pure sample-parallel layers execute no shard_map: their dL/dw
        allreduce is GSPMD-inserted (visibility='gspmd').
    """
    ws = m.wordsize
    n_l = layer.n // max(dist.ways("N", mesh_shape), 1)
    h_ways = dist.ways("H", mesh_shape)
    w_ways = dist.ways("W", mesh_shape)
    h_l = layer.h // max(h_ways, 1)
    w_l = layer.w // max(w_ways, 1)
    h_out_l = layer.h_out // max(h_ways, 1)
    w_out_l = layer.w_out // max(w_ways, 1)
    p_c = dist.ways("C", mesh_shape)
    p_f = dist.ways("F", mesh_shape)
    p_cf = max(p_c, p_f)
    cf = p_cf > 1
    spatial = h_ways > 1 or w_ways > 1
    mode = cf_mode_for(layer, dist, mesh_shape) if cf else None

    batch_axes = tuple(dist.axes("N"))
    h_axes = tuple(dist.axes("H")) if h_ways > 1 else ()
    w_axes = tuple(dist.axes("W")) if w_ways > 1 else ()
    cf_axes = tuple(dist.axes("C")) if p_c > 1 else tuple(dist.axes("F"))
    grad_axes = batch_axes + h_axes + w_axes

    specs: list[CollectiveSpec] = []

    # ---- spatial halo ppermutes (fwd + transposed bwd) --------------------
    if spatial:
        lo, hi = same_pads(layer.k, layer.s)
        nper = (lo > 0) + (hi > 0)
        if cf:
            # CF x spatial: 'channel' mode halos the local C-block,
            # 'filter' mode halos the already-gathered full-C x.
            c_halo = layer.c // p_cf if mode == "channel" else layer.c
        else:
            c_halo = layer.c // max(p_c, 1)
        halos = []
        if nper and h_ways > 1:
            halos.append((h_axes, n_l * (lo + hi) * w_l * c_halo * ws))
        if nper and w_ways > 1:
            rows = h_l + ((lo + hi) if h_ways > 1 else 0)
            halos.append((w_axes, n_l * rows * (lo + hi) * c_halo * ws))
        for axes, nbytes in halos:
            specs.append(CollectiveSpec(
                "ppermute", "halo_exchange", "fwd", nper, nbytes, axes,
                term="fp"))
            if not first:
                specs.append(CollectiveSpec(
                    "ppermute", "halo_exchange", "bwd", nper, nbytes, axes,
                    term="bpw" if overlap else "bpx"))

    if layer.kind != "conv":
        return specs

    # ---- weight-gradient psums -------------------------------------------
    w_words = layer.k ** 2 * layer.c * layer.f
    if cf:
        blk_words = w_words // p_cf
        p_total = 1
        for _, sz in mesh_shape.items():
            p_total *= sz
        p_ar = p_total // max(p_c * p_f, 1)
        # CF x spatial layers run the same interior/boundary halo split as
        # the pure-spatial path, and the weight-block contraction psums
        # once per conv application there too.
        apps = 1
        if spatial and overlap:
            g = _conv_split_geometry(layer, dist, mesh_shape)
            if g is not None:
                _, do_loc, lo, hi, t_lo, t_hi = g
                if (lo or hi) and t_lo + t_hi < do_loc:
                    apps = 1 + (t_lo > 0) + (t_hi > 0)
        specs.append(CollectiveSpec(
            "psum", "conv", "bwd", apps, apps * blk_words * ws, grad_axes,
            term="bpa", charged=p_ar > 1))
        # slice-VJP of the weight block: the cotangent is scattered back
        # into the full weight shape and psummed over the CF axis — comm
        # no cost term prices.
        specs.append(CollectiveSpec(
            "psum", "cf_w_vjp", "bwd", 1, w_words * ws, cf_axes,
            term="none", charged=False))
    elif spatial:
        g = _conv_split_geometry(layer, dist, mesh_shape)
        apps = 1
        if g is not None and interior_split(layer, dist, mesh_shape,
                                            overlap):
            _, _, _, _, t_lo, t_hi = g
            apps = 1 + (t_lo > 0) + (t_hi > 0)
        specs.append(CollectiveSpec(
            "psum", "conv", "bwd", apps, apps * w_words * ws, grad_axes,
            term="bpa"))
    else:
        # no shard_map at all: GSPMD inserts the data-parallel grad
        # allreduce after partitioning — invisible to the jaxpr walk.
        p_total = 1
        for _, sz in mesh_shape.items():
            p_total *= sz
        if p_total > 1:
            specs.append(CollectiveSpec(
                "psum", "gspmd", "bwd", 1, w_words * ws, batch_axes,
                term="bpa", visibility="gspmd"))

    # ---- CF data collectives ---------------------------------------------
    if cf:
        n_blk = channel_chunks if (overlap and not spatial) else 1
        n_blk = max(1, min(n_blk, layer.c // p_cf))
        if mode == "channel":
            specs.append(CollectiveSpec(
                "reduce_scatter", "cf_reduce_scatter", "fwd", n_blk,
                n_l * h_out_l * w_out_l * layer.f * ws, cf_axes,
                term="fp"))
            specs.append(CollectiveSpec(
                "all_gather", "cf_reduce_scatter", "bwd", n_blk,
                n_blk * n_l * h_out_l * w_out_l * (layer.f // p_cf) * ws,
                cf_axes, term="bpw"))
        else:
            specs.append(CollectiveSpec(
                "all_gather", "cf_all_gather", "fwd", 1,
                n_l * h_l * w_l * (layer.c // p_cf) * ws, cf_axes,
                term="fp"))
            specs.append(CollectiveSpec(
                "reduce_scatter", "cf_all_gather", "bwd", 1,
                n_l * h_l * w_l * layer.c * ws, cf_axes, term="bpx"))
    return specs


# ---------------------------------------------------------------------------
# per-device memory under a distribution (the §VI Table-2 forcing function)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerMemory:
    """Per-device resident bytes of one layer under a distribution — the
    memory companion of LayerCost.  All fields are bytes on ONE device.

    `stash` is what the layer leaves resident for the backward pass,
    calibrated against XLA buffer assignments of the compiled runtime:
    the input activation (dL/dw contracts against x; max-pool backward
    needs its input), the halo-extended input copy autodiff saves inside
    the shard_map (its conv-transpose primal), and the pre-BN output (BN
    backward) — 2 x act_in + act_out.  The post-ReLU tensor is the next
    layer's act_in, counted there.  The stash *contains* the act_in/
    act_out working buffers, so `total` adds it (not them) on top of the
    persistent words and communication scratch; `network_memory`
    accumulates it across layers — the residency that dominates
    whole-network peaks.
    """
    weights: float = 0.0      # resident weight shard (replicated unless CF)
    grads: float = 0.0        # dL/dw, sharded like the weights
    opt: float = 0.0          # optimizer state (opt_words x weight words)
    act_in: float = 0.0       # input activation shard (local extents)
    act_out: float = 0.0      # output activation shard (h_out/w_out extents)
    stash: float = 0.0        # fwd residency for backward (2*act_in+act_out)
    halo: float = 0.0         # neighbor-halo recv buffers (max of fwd/bwd)
    cf: float = 0.0           # CF AG(x)/RS(y) staging buffer (executed mode)

    @property
    def persistent(self) -> float:
        """Bytes resident for the whole step (weights + grads + opt)."""
        return self.weights + self.grads + self.opt

    @property
    def transient(self) -> float:
        """Communication scratch live only while this layer runs."""
        return self.halo + self.cf

    @property
    def total(self) -> float:
        """This layer's own resident set — the per-layer solver constraint:
        persistent words + the backward stash (which includes the act_in/
        act_out working buffers) + communication scratch."""
        return self.persistent + self.stash + self.transient

    def breakdown(self) -> str:
        parts = [(k, getattr(self, k))
                 for k in ("weights", "grads", "opt", "act_in", "act_out",
                           "halo", "cf")]
        return " ".join(f"{k}={human_bytes(v)}" for k, v in parts if v)


def layer_memory(m: Machine, layer: ConvLayer, dist: Dist,
                 mesh_shape: Mapping[str, int],
                 opt_words: float = 1.0) -> LayerMemory:
    """Per-device memory footprint of `layer` under `dist` (bytes).

    Accounts, per shard: weights (replicated across sample/spatial
    processors; C/F-sharded by the CF group size under a CF dist — both
    §III-D modes hold weight_words/p_cf resident), input/output activations
    at the sharded extents (outputs at h_out/w_out — pooling and strided
    layers shrink, matching act_words), the forward stash kept for
    backward, halo recv buffers (the core.halo geometry: lo+hi slabs per
    split dim plus the 4 corner blocks when both H and W split; product
    axes divide the extents through dist.ways, so the buffers are
    hop-count independent), the CF collective staging buffer of the mode
    the runtime executes (cf_mode_for's min), and gradient + optimizer
    words (`opt_words` per weight word; SGD+momentum = 1, Adam = 2).
    """
    ws = m.wordsize
    n_l = layer.n / max(dist.ways("N", mesh_shape), 1)
    h_l = layer.h / max(dist.ways("H", mesh_shape), 1)
    w_l = layer.w / max(dist.ways("W", mesh_shape), 1)
    c_l = layer.c / max(dist.ways("C", mesh_shape), 1)
    f_l = layer.f / max(dist.ways("F", mesh_shape), 1)
    h_out_l = layer.h_out / max(dist.ways("H", mesh_shape), 1)
    w_out_l = layer.w_out / max(dist.ways("W", mesh_shape), 1)
    p_cf = max(dist.ways("C", mesh_shape), dist.ways("F", mesh_shape))

    mem = LayerMemory()
    w_words = layer.weight_words() / max(p_cf, 1)
    mem.weights = w_words * ws
    mem.grads = w_words * ws
    mem.opt = opt_words * w_words * ws
    mem.act_in = n_l * c_l * h_l * w_l * ws
    mem.act_out = n_l * f_l * h_out_l * w_out_l * ws
    mem.stash = 2 * mem.act_in + mem.act_out

    o = layer.o
    h_split = dist.ways("H", mesh_shape) > 1
    w_split = dist.ways("W", mesh_shape) > 1
    if o and (h_split or w_split):
        # forward halo carries C channels at input extents; the backward
        # halo carries F channels of dL/dy at output extents.  They do not
        # coexist, so the resident buffer is the max of the two.
        halo_x = halo_dy = 0.0
        if h_split:
            halo_x += 2 * o * n_l * c_l * w_l
            halo_dy += 2 * o * n_l * f_l * w_out_l
        if w_split:
            halo_x += 2 * o * n_l * c_l * h_l
            halo_dy += 2 * o * n_l * f_l * h_out_l
        if h_split and w_split:
            halo_x += 4 * o * o * n_l * c_l
            halo_dy += 4 * o * o * n_l * f_l
        mem.halo = max(halo_x, halo_dy) * ws
    if p_cf > 1:
        # the staging buffer of the executed §III-D mode: 'filter' holds
        # the gathered full-C x, 'channel' the full-F partial y before its
        # reduce-scatter — cf_mode_for picks whichever is smaller.
        words = cf_collective_words(layer, dist, mesh_shape)
        mem.cf = min(words["ag_x"], words["rs_y"]) * ws
    return mem


def network_memory(m: Machine, layers: Sequence[ConvLayer],
                   dists: Sequence[Dist], mesh_shape: Mapping[str, int],
                   opt_words: float = 1.0) -> dict:
    """Per-device peak resident bytes for a network under per-layer dists.

    The rollup mirrors a training step's residency: every layer's
    weights/grads/optimizer words are live throughout; walking forward,
    layer i's working set (act_in/out, halo, CF staging) coexists with the
    stashed activations of all *earlier* layers — the accumulation that
    makes large-sample workloads unreachable under sample parallelism
    (paper §VI, Table 2).  Returns per-layer LayerMemory breakdowns plus
    `peak_bytes` and the layer where the peak occurs.
    """
    assert len(layers) == len(dists)
    mems = [layer_memory(m, l, d, mesh_shape, opt_words)
            for l, d in zip(layers, dists)]
    persistent = sum(lm.persistent for lm in mems)
    peak, peak_layer, stash_acc = 0.0, None, 0.0
    for l, lm in zip(layers, mems):
        stash_acc += lm.stash          # this layer's working set included
        live = persistent + stash_acc + lm.transient
        if live > peak:
            peak, peak_layer = live, l.name
    return {"per_layer": mems, "persistent_bytes": persistent,
            "peak_bytes": peak, "peak_layer": peak_layer}


def shuffle_block_bytes(layer: ConvLayer, p: int, wordsize: int) -> int:
    """Per-processor payload of a §III-C shuffle of ℓ's output: the one
    definition shared by shuffle_time and calibrate's shuffle-size grid, so
    measured `shuffle:` table keys match the keys priced plans look up."""
    return int(layer.act_words() / max(p, 1) * wordsize)


def shuffle_time(m: Machine, layer: ConvLayer, d_i: Dist, d_j: Dist,
                 mesh_shape: Mapping[str, int],
                 table: EmpiricalTable | None = None) -> float:
    """Shuffle(D_i, D_j): all-to-all redistribution of ℓ's output (§III-C).

    Prefers a measured `shuffle:` table entry at (p, local_bytes) — exact or
    size-interpolated — over the analytic pairwise model; the analytic
    fallback is scaled by the machine's fitted shuffle_factor."""
    if d_i.same_as(d_j):
        return 0.0
    p = 1
    for ax, sz in mesh_shape.items():
        p *= sz
    local_bytes = shuffle_block_bytes(layer, p, m.wordsize)
    # forward shuffle of y and backward shuffle of dL/dx
    if table is not None:
        t = table.lookup_shuffle(p, local_bytes)
        if t is not None:
            return 2 * t
    return 2 * all_to_all_time(m, p, local_bytes) * m.shuffle_factor


# ---------------------------------------------------------------------------
# whole-network cost (paper §V-B)
# ---------------------------------------------------------------------------

def network_cost(m: Machine, layers: Sequence[ConvLayer],
                 dists: Sequence[Dist], mesh_shape: Mapping[str, int],
                 table: EmpiricalTable | None = None,
                 overlap: bool = True,
                 eff: float | None = None) -> dict:
    """End-to-end mini-batch time for a line network under per-layer dists.

    Greedy allreduce overlap (§V-B): walking backprop from the last layer,
    each dL/dw allreduce starts when (a) its layer's backprop is done and
    (b) the previous allreduce finished (one at a time); it runs concurrent
    with the remaining backprop compute.  The mini-batch ends when both the
    compute timeline and the last allreduce finish.
    """
    assert len(layers) == len(dists)
    costs = [layer_cost(m, l, d, mesh_shape, table, overlap, eff)
             for l, d in zip(layers, dists)]

    fp_time = sum(c.fp for c in costs)
    shuf = sum(shuffle_time(m, layers[i], dists[i], dists[i + 1], mesh_shape,
                            table)
               for i in range(len(layers) - 1))

    # backward timeline with greedy allreduce overlap
    t = 0.0          # compute-stream clock
    ar_free = 0.0    # when the collective stream is free
    ar_end = 0.0
    for c in reversed(costs):
        t += c.bpx + c.bpw
        if c.bpa > 0:
            start = max(t, ar_free)
            ar_free = start + c.bpa
            ar_end = ar_free
    bp_time = max(t, ar_end) if overlap else \
        sum(c.bpx + c.bpw + c.bpa for c in costs)

    return {"total": fp_time + shuf + bp_time, "fp": fp_time,
            "bp": bp_time, "shuffle": shuf,
            "exposed_allreduce": max(0.0, ar_end - t) if overlap else
            sum(c.bpa for c in costs),
            "per_layer": costs}
