"""Channel/filter-parallel convolution (paper §III-D) — the runtime.

The paper sketches partitioning the *hidden* dimensions of a conv layer: the
C input channels and the F filters (output channels).  This module makes
those distributions executable, as the convolution analogue of Megatron's
row/column-parallel linear layers:

  'channel' (row-parallel, the scheme the §V perf model costs):
      x enters C-sharded; each processor holds the C-rows of w for its
      channel block and convolves them against *all* F filters, producing a
      full-F partial sum; a reduce-scatter over the CF mesh axis completes
      the channel sum (Eq. 1's sum over c) and leaves y F-sharded.  The VJP
      of the reduce-scatter is the all-gather that hands backprop the full-F
      dL/dy it needs for the filter-gradient contraction (§III-D's
      allreduce, in its reduce-scatter/all-gather factorization).

  'filter' (column-parallel):
      x is all-gathered over the CF axis to full C; each processor convolves
      against its F-block of w, so y comes out F-sharded with no output
      collective.  Backprop reverses the all-gather into a psum on dL/dx.

Both modes consume C-sharded input and produce F-sharded output under the
*same* PartitionSpec, so consecutive CF layers chain with zero resharding —
layer i's F-shard IS layer i+1's C-shard — and a §III-C shuffle appears
exactly when the plan transitions between CF and sample/spatial layers.

Weights stay *globally* addressed (replicated into the shard_map, sliced
per-shard with `axis_index`): parameter trees, checkpoints and the FSDP
at-rest sharding are untouched, and autodiff reconstitutes the full dL/dw
through the slice-VJP + shard_map psum, which is the §V-A allreduce over the
processors sharing each (C, F) block.

BN under a CF distribution is embarrassingly parallel over channels (the
statistics are per-channel), so `cf_batch_norm` needs *zero* communication
at 'local'/'spatial' scope and a batch-axes-only psum at 'global' scope —
one of the paper's arguments for channel decompositions of late layers.

All functions replicate single-device convolution exactly (up to float
accumulation order), like their spatial counterparts.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.spatial_conv import _conv_nhwc
from repro.utils import same_pads, shard_map

MODES = ("channel", "filter")


@dataclasses.dataclass(frozen=True)
class CFSharding:
    """Distribution descriptor for a channel/filter-parallel conv layer.

    batch_axes: mesh axes sharding N (sample parallelism), as ConvSharding.
    cf_axis:    the mesh axis partitioning C of the input and F of the
                output (one axis — the §III-D group).
    mode:       'channel' (row-parallel, reduce-scatter on y — the perf
                model's costing) or 'filter' (column-parallel, all-gather
                on x).
    """
    batch_axes: tuple[str, ...] = ()
    cf_axis: str | None = None
    mode: str = "channel"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"CFSharding mode {self.mode!r} not in {MODES}")

    # duck-type the ConvSharding surface the models/plan query ------------
    @property
    def is_spatial(self) -> bool:
        return False

    @property
    def h_axis(self):
        return None

    @property
    def w_axis(self):
        return None

    def x_spec(self) -> P:
        """NHWC placement: channels on the CF axis, N on the batch axes."""
        return P(self.batch_axes or None, None, None, self.cf_axis)

    def fit(self, h: int, w: int, k: int, s: int, mesh) -> "CFSharding":
        """Spatial-geometry fit is a no-op for CF layers (nothing spatial is
        sharded); channel divisibility is validated at plan-compile time
        (core.plan demotes non-divisible layers and records it)."""
        return self

    def fits_channels(self, c: int, f: int, mesh_shape) -> bool:
        if self.cf_axis is None:
            return True
        ways = dict(mesh_shape).get(self.cf_axis, 1)
        return c % ways == 0 and f % ways == 0


def _resolve_mesh(mesh):
    """The ambient abstract mesh, on jax versions that track one."""
    if mesh is not None:
        return mesh
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    return gam() if gam is not None else None


def _slice_block(v, axis_name: str, n_blocks: int, dim: int):
    """This shard's block of a replicated array, along `dim`."""
    size = v.shape[dim] // n_blocks
    return lax.dynamic_slice_in_dim(v, lax.axis_index(axis_name) * size,
                                    size, axis=dim)


def _local_cf_conv(x, w, *, strides, sharding: CFSharding, mesh_shape,
                   backend: str = "xla"):
    """Shard-local CF conv (runs inside shard_map).

    x: this shard's (n_local, H, W, C/p) channel block.
    w: the full (K, K, C, F) weights (replicated into the shard_map).
    """
    ax = sharding.cf_axis
    p = mesh_shape[ax]
    k_h, k_w = w.shape[0], w.shape[1]
    pads = (same_pads(k_h, strides[0]), same_pads(k_w, strides[1]))

    if sharding.mode == "filter":
        # column-parallel: restore full C, convolve my F-block. y needs no
        # collective; the all-gather's VJP is the psum completing dL/dx.
        xg = lax.all_gather(x, ax, axis=3, tiled=True)
        wp = _slice_block(w, ax, p, dim=3)
        return _conv_nhwc(xg, wp, strides, pads, backend)

    # row-parallel: my C-rows of w against all F filters, then the
    # reduce-scatter that completes the channel sum and leaves y F-sharded.
    wp = _slice_block(w, ax, p, dim=2)
    partial = _conv_nhwc(x, wp, strides, pads, backend)
    return lax.psum_scatter(partial, ax, scatter_dimension=3, tiled=True)


def cf_conv2d(x, w, *, strides=(1, 1), sharding: CFSharding, mesh=None,
              overlap: bool = True, backend: str = "xla"):
    """'SAME'-padded strided conv2d under channel/filter parallelism.

    x: (N, H, W, C) global array, C sharded on `sharding.cf_axis` (and N on
       the batch axes) under jit.
    w: (K_h, K_w, C, F) weights, globally addressed (replicated into the
       shard, sliced per-processor — FSDP owns the at-rest layout).
    overlap: accepted for API symmetry with spatial_conv2d; the CF
       collectives are exposed to XLA's latency-hiding scheduler as
       ordinary dataflow, no manual interior/boundary split is needed.
    backend: 'xla' or 'pallas' — the local conv kernel (see _conv_nhwc).
    """
    if x.dtype != w.dtype:      # mixed-precision policy: compute in w's dtype
        x = x.astype(w.dtype)
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    k_h, k_w = w.shape[0], w.shape[1]
    if p <= 1:
        # dense fallback — the 1x1-mesh oracle path, bitwise-identical.
        return _conv_nhwc(x, w, strides,
                          (same_pads(k_h, strides[0]),
                           same_pads(k_w, strides[1])), backend)
    c, f = w.shape[2], w.shape[3]
    if c % p or f % p:
        # hard error, not an assert: under `python -O` a stripped assert
        # would let _slice_block truncate the channel sum silently
        raise ValueError(
            f"channels C={c}, F={f} not divisible by {p}-way CF axis "
            f"{sharding.cf_axis!r} — core.plan demotes such layers at "
            "compile time; direct callers must pre-check "
            "CFSharding.fits_channels")
    fn = functools.partial(_local_cf_conv, strides=strides,
                           sharding=sharding, mesh_shape=mesh_shape,
                           backend=backend)
    spec = sharding.x_spec()
    # legacy replication tracking has no rule for pallas_call, so the
    # Pallas local-conv CF path drops it (forward-verified; take gradients
    # through the XLA backend on legacy jax — see utils.shard_map).
    lcr = False if backend == "pallas" else None
    return shard_map(fn, mesh=mesh, in_specs=(spec, P()),
                     out_specs=spec, legacy_check_rep=lcr)(x, w)


def cf_bias_add(x, b, *, sharding: CFSharding, mesh=None):
    """Add a per-channel bias to a C-sharded NHWC tensor (b stays global)."""
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    if p <= 1:
        return x + b
    spec = sharding.x_spec()

    def fn(x, b):
        return x + _slice_block(b, sharding.cf_axis, p, dim=0)

    return shard_map(fn, mesh=mesh, in_specs=(spec, P()),
                     out_specs=spec)(x, b)


def cf_batch_norm(x, gamma, beta, *, sharding: CFSharding, mesh=None,
                  scope: str = "local", eps: float = 1e-5):
    """BN over (N, H, W) of a C-sharded NHWC tensor.

    Per-channel statistics never cross the CF axis (each channel lives on
    exactly one shard), so 'local' and 'spatial' scopes are communication-
    free; 'global' psums the moments over the batch axes only.  gamma/beta
    stay globally addressed, sliced per shard like the conv weights.
    """
    if scope not in ("local", "spatial", "global"):
        raise ValueError(f"unknown BN scope {scope!r}")
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    comm_axes = tuple(a for a in (sharding.batch_axes or ())
                      if scope == "global" and mesh_shape.get(a, 1) > 1)
    if p <= 1 and not comm_axes:
        # dense fallback, formulated exactly like core.spatial_norm's local
        # path so the 1x1-mesh numerics are bitwise-identical
        xf = x.astype(jnp.float32)
        n = x.shape[0] * x.shape[1] * x.shape[2]
        mean = jnp.sum(xf, (0, 1, 2)) / n
        var = jnp.sum(jnp.square(xf), (0, 1, 2)) / n - jnp.square(mean)
        inv = lax.rsqrt(var + eps)
        y = (x - mean.astype(x.dtype)) * inv.astype(x.dtype)
        return y * gamma + beta

    def fn(x, g, b):
        xf = x.astype(jnp.float32)
        s = jnp.sum(xf, (0, 1, 2))
        ss = jnp.sum(jnp.square(xf), (0, 1, 2))
        n = x.shape[0] * x.shape[1] * x.shape[2]
        if comm_axes:
            s = lax.psum(s, comm_axes)
            ss = lax.psum(ss, comm_axes)
            for a in comm_axes:
                n *= mesh_shape[a]
        mean = s / n
        var = ss / n - jnp.square(mean)
        inv = lax.rsqrt(var + eps)
        y = (x - mean.astype(x.dtype)) * inv.astype(x.dtype)
        if p > 1:
            g = _slice_block(g, sharding.cf_axis, p, dim=0)
            b = _slice_block(b, sharding.cf_axis, p, dim=0)
        return y * g + b

    spec = sharding.x_spec()
    return shard_map(fn, mesh=mesh, in_specs=(spec, P(), P()),
                     out_specs=spec)(x, gamma, beta)
