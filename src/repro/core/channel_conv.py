"""Channel/filter-parallel convolution (paper §III-D) — the runtime.

The paper sketches partitioning the *hidden* dimensions of a conv layer: the
C input channels and the F filters (output channels).  This module makes
those distributions executable, as the convolution analogue of Megatron's
row/column-parallel linear layers:

  'channel' (row-parallel, the scheme the §V perf model costs):
      x enters C-sharded; each processor holds the C-rows of w for its
      channel block and convolves them against *all* F filters, producing a
      full-F partial sum; a reduce-scatter over the CF mesh axis completes
      the channel sum (Eq. 1's sum over c) and leaves y F-sharded.  The VJP
      of the reduce-scatter is the all-gather that hands backprop the full-F
      dL/dy it needs for the filter-gradient contraction (§III-D's
      allreduce, in its reduce-scatter/all-gather factorization).

  'filter' (column-parallel):
      x is all-gathered over the CF axis to full C; each processor convolves
      against its F-block of w, so y comes out F-sharded with no output
      collective.  Backprop reverses the all-gather into a psum on dL/dx.

Both modes consume C-sharded input and produce F-sharded output under the
*same* PartitionSpec, so consecutive CF layers chain with zero resharding —
layer i's F-shard IS layer i+1's C-shard — and a §III-C shuffle appears
exactly when the plan transitions between CF and sample/spatial layers.

CF x spatial composition (the 16x16-mesh unlock): a `CFSharding` may also
carry `h_axis`/`w_axis` on *different* mesh axes than `cf_axis`.  The halo
exchange on H/W and the CF collective then live inside ONE shard_map — the
Megatron-style composition of tensor-parallel collectives with another
parallel axis — with the §IV-A interior/boundary overlap split preserved on
the spatial dims (the halo ppermute is dataflow-independent of the interior
conv, so XLA's latency-hiding scheduler can run them concurrently).

Overlapped channel mode (§IV-A analogue for the hidden dimension): with
``overlap=True`` and ``channel_chunks > 1`` the local conv is split into
channel blocks and each block's partial sum is reduce-scattered as it
completes — the psum_scatter of block b pipelines with the convolution of
block b+1, which is what the perf model's η-scaled overlap credit charges
CF layers with.  The chunk count defaults from the *calibrated* achieved-
overlap efficiency η (see chunks_decision: 2 on TPU, 2 when a measured
η ≥ 0.5 says overlap actually pays, 1 otherwise); psum_scatter is linear,
so summing the scattered partials is numerically a reordering of the
single-collective channel sum.

Weights stay *globally* addressed (replicated into the shard_map, sliced
per-shard with `axis_index`): parameter trees, checkpoints and the FSDP
at-rest sharding are untouched, and autodiff reconstitutes the full dL/dw
through the slice-VJP + shard_map psum, which is the §V-A allreduce over the
processors sharing each (C, F) block.

BN under a CF distribution is embarrassingly parallel over channels (the
statistics are per-channel), so `cf_batch_norm` needs *zero* communication
at 'local'/'spatial' scope and a batch-axes-only psum at 'global' scope —
one of the paper's arguments for channel decompositions of late layers.

All functions replicate single-device convolution exactly (up to float
accumulation order), like their spatial counterparts.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import halo as halo_lib
from repro.core import trace as trace_lib
from repro.core.spatial_conv import (ConvSharding, _conv_nhwc, _local_conv,
                                     cast_to_weight_dtype, fit_spatial_axis,
                                     spatial_conv2d)
from repro.utils import replication_policy, same_pads, shard_map

MODES = ("channel", "filter")

# ---------------------------------------------------------------------------
# calibrated chunked-CF default (replaces PR 4's hard `1 off-TPU` paper-over)
# ---------------------------------------------------------------------------

# the measured achieved-overlap efficiency (Machine.overlap_eta), installed
# by core.calibrate whenever a calibration with live overlap samples runs or
# loads; None means "no measurement yet — assume nothing".
_MEASURED_ETA: float | None = None

# chunking must hide at least this fraction of the hideable min(comm,
# compute) to pay for its extra per-block collective launches and slices.
ETA_CHUNK_THRESHOLD = 0.5


def set_measured_eta(eta: float | None) -> None:
    """Install (or clear with None) the calibrated η that
    default_channel_chunks resolves against — called by core.calibrate
    after a fit or load that carries real overlap samples."""
    global _MEASURED_ETA
    _MEASURED_ETA = eta


def measured_eta() -> float | None:
    return _MEASURED_ETA


def chunks_decision() -> tuple[int, str]:
    """The calibrated 'channel'-mode chunk default, with its reason.

    Chunking pipelines the psum_scatter of block b with the conv of block
    b+1, which only pays when the machine demonstrably hides collectives
    behind compute: TPU's async collective engine does by construction;
    elsewhere chunking needs a *measured* η ≥ ETA_CHUNK_THRESHOLD.  With no
    calibration it stays off — PR 4 measured chunked CF as pure overhead on
    host XLA, and that evidence (not a hardcoded backend switch) is what
    this default now encodes."""
    if jax.default_backend() == "tpu":
        return 2, "tpu async collectives"
    if _MEASURED_ETA is None:
        return 1, "eta unmeasured"
    if _MEASURED_ETA >= ETA_CHUNK_THRESHOLD:
        return 2, f"measured eta {_MEASURED_ETA:.2f} >= {ETA_CHUNK_THRESHOLD}"
    return 1, f"measured eta {_MEASURED_ETA:.2f} < {ETA_CHUNK_THRESHOLD}"


def default_channel_chunks() -> int:
    return chunks_decision()[0]


@dataclasses.dataclass(frozen=True)
class CFSharding:
    """Distribution descriptor for a channel/filter-parallel conv layer.

    batch_axes: mesh axes sharding N (sample parallelism), as ConvSharding.
    cf_axis:    the mesh axis partitioning C of the input and F of the
                output (one axis — the §III-D group).
    mode:       'channel' (row-parallel, reduce-scatter on y — the perf
                model's costing) or 'filter' (column-parallel, all-gather
                on x).  The plan compiler picks per layer from the
                AG(x)-vs-RS(y) message sizes (core.plan).
    h_axis / w_axis: optional spatial sharding of H / W on *different* mesh
                axes than `cf_axis` (each may be a tuple forming a product
                axis, core.halo) — the CF x spatial composition: halo
                exchange and CF collective in one shard_map.
    """
    batch_axes: tuple[str, ...] = ()
    cf_axis: str | None = None
    mode: str = "channel"
    h_axis: str | tuple[str, ...] | None = None
    w_axis: str | tuple[str, ...] | None = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"CFSharding mode {self.mode!r} not in {MODES}")
        overlap_axes = {self.cf_axis} & set(self.spatial_axes)
        if overlap_axes:
            raise ValueError(
                f"CFSharding cf_axis {self.cf_axis!r} also shards a spatial "
                f"dim — the CF collective and the halo exchange must live "
                f"on different mesh axes")

    @property
    def is_spatial(self) -> bool:
        return self.h_axis is not None or self.w_axis is not None

    @property
    def h_axes(self) -> tuple[str, ...]:
        return halo_lib.axes_tuple(self.h_axis)

    @property
    def w_axes(self) -> tuple[str, ...]:
        return halo_lib.axes_tuple(self.w_axis)

    @property
    def spatial_axes(self) -> tuple[str, ...]:
        return self.h_axes + self.w_axes

    def x_spec(self) -> P:
        """NHWC placement: channels on the CF axis, N on the batch axes,
        H/W on the spatial axes when composed."""
        return P(self.batch_axes or None, self.h_axis, self.w_axis,
                 self.cf_axis)

    def fit(self, h: int, w: int, k: int, s: int, mesh) -> "CFSharding":
        """Apply the §III-A geometry fit to the composed spatial axes (the
        CF group is untouched); channel divisibility is validated at
        plan-compile time (core.plan demotes non-divisible layers and
        records it)."""
        if mesh is None or not self.is_spatial:
            return self
        shape = dict(mesh.shape)
        return dataclasses.replace(
            self,
            h_axis=fit_spatial_axis(h, self.h_axis, k, s, shape),
            w_axis=fit_spatial_axis(w, self.w_axis, k, s, shape))

    def fits_channels(self, c: int, f: int, mesh_shape) -> bool:
        if self.cf_axis is None:
            return True
        ways = dict(mesh_shape).get(self.cf_axis, 1)
        return c % ways == 0 and f % ways == 0


def _resolve_mesh(mesh):
    """The ambient abstract mesh, on jax versions that track one."""
    if mesh is not None:
        return mesh
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    return gam() if gam is not None else None


def _slice_block(v, axis_name: str, n_blocks: int, dim: int):
    """This shard's block of a replicated array, along `dim`."""
    size = v.shape[dim] // n_blocks
    return lax.dynamic_slice_in_dim(v, lax.axis_index(axis_name) * size,
                                    size, axis=dim)


def _conv_local_block(x, w, *, strides, sharding: CFSharding, mesh_shape,
                      overlap, backend):
    """Local conv of a (possibly spatially sharded) block with the already-
    sliced weights `w`: plain dense when nothing spatial is sharded, else
    the halo-exchange path of core.spatial_conv — including the §IV-A
    interior/boundary split — on the composed H/W axes."""
    if not sharding.is_spatial:
        k_h, k_w = w.shape[0], w.shape[1]
        return _conv_nhwc(x, w, strides,
                          (same_pads(k_h, strides[0]),
                           same_pads(k_w, strides[1])), backend)
    spatial_view = ConvSharding(h_axis=sharding.h_axis,
                                w_axis=sharding.w_axis)
    return _local_conv(x, w, strides=strides, sharding=spatial_view,
                       mesh_shape=mesh_shape, overlap=overlap,
                       backend=backend)


def _local_cf_conv(x, w, *, strides, sharding: CFSharding, mesh_shape,
                   overlap: bool = True, backend: str = "xla",
                   channel_chunks: int = 1):
    """Shard-local CF conv (runs inside shard_map).

    x: this shard's (n_local, H_local, W_local, C/p) channel block — the
       spatial extents are local too when the sharding composes CF with
       spatial axes.
    w: the full (K, K, C, F) weights (replicated into the shard_map).
    channel_chunks: 'channel'-mode §IV-A split granularity (see cf_conv2d).
    """
    ax = sharding.cf_axis
    p = mesh_shape[ax]

    if sharding.mode == "filter":
        # column-parallel: restore full C, convolve my F-block (with its
        # halo when spatial axes compose in).  y needs no collective; the
        # all-gather's VJP is the reduce-scatter completing dL/dx.
        with trace_lib.annotate("cf_all_gather"):
            xg = lax.all_gather(x, ax, axis=3, tiled=True)
        wp = _slice_block(w, ax, p, dim=3)
        return _conv_local_block(xg, wp, strides=strides, sharding=sharding,
                                 mesh_shape=mesh_shape, overlap=overlap,
                                 backend=backend)

    # row-parallel: my C-rows of w against all F filters, then the
    # reduce-scatter that completes the channel sum and leaves y F-sharded.
    wp = _slice_block(w, ax, p, dim=2)
    c_loc = x.shape[3]
    n_blk = channel_chunks if overlap and not sharding.is_spatial else 1
    n_blk = max(1, min(n_blk, c_loc))
    if n_blk <= 1:
        # single-collective path.  Under CF x spatial composition the
        # §IV-A overlap comes from the interior/boundary split inside
        # _conv_local_block — chunking the channels on top would repeat
        # the halo exchange per block, paying its latency twice.
        partial = _conv_local_block(x, wp, strides=strides,
                                    sharding=sharding,
                                    mesh_shape=mesh_shape, overlap=overlap,
                                    backend=backend)
        with trace_lib.annotate("cf_reduce_scatter"):
            return lax.psum_scatter(partial, ax, scatter_dimension=3,
                                    tiled=True)

    # overlapped channel mode (§IV-A analogue): convolve per channel block
    # and reduce-scatter each partial as it completes, so the collective of
    # block b pipelines with the compute of block b+1.  psum_scatter is
    # linear, so the summed scattered partials equal the single-collective
    # channel sum up to float reassociation.
    bounds = [round(i * c_loc / n_blk) for i in range(n_blk + 1)]
    y = None
    for lo, hi in zip(bounds, bounds[1:]):
        if hi <= lo:
            continue
        partial = _conv_local_block(
            lax.slice_in_dim(x, lo, hi, axis=3),
            lax.slice_in_dim(wp, lo, hi, axis=2),
            strides=strides, sharding=sharding, mesh_shape=mesh_shape,
            overlap=overlap, backend=backend)
        with trace_lib.annotate("cf_reduce_scatter"):
            scat = lax.psum_scatter(partial, ax, scatter_dimension=3,
                                    tiled=True)
        y = scat if y is None else y + scat
    return y


def cf_conv2d(x, w, *, strides=(1, 1), sharding: CFSharding, mesh=None,
              overlap: bool = True, backend: str = "xla",
              channel_chunks: int | None = None):
    """'SAME'-padded strided conv2d under channel/filter parallelism,
    optionally composed with spatial parallelism on different mesh axes.

    x: (N, H, W, C) global array, C sharded on `sharding.cf_axis` (N on
       the batch axes, H/W on the spatial axes when composed) under jit.
    w: (K_h, K_w, C, F) weights, globally addressed (replicated into the
       shard, sliced per-processor — FSDP owns the at-rest layout).
    overlap: enables the §IV-A-style splits that make communication
       independent of interior compute in dataflow: the interior/boundary
       split on composed spatial dims, and in 'channel' mode the
       channel-block split that pipelines the psum_scatter with the local
       conv (see _local_cf_conv).
    channel_chunks: 'channel'-mode block count for that split.  None (the
       default) resolves through chunks_decision(): 2 on TPU — where the
       latency-hiding scheduler actually runs the scattered partial of
       block b under the conv of block b+1 — 2 when core.calibrate has
       measured an achieved-overlap η ≥ ETA_CHUNK_THRESHOLD on this mesh,
       and 1 otherwise (with no evidence that collectives hide behind
       compute, extra collectives are pure overhead — measured so in
       benchmarks/strategy_exec).  Tests pass an explicit 2 to pin the
       chunked path's numerics on any backend.
    backend: 'xla' or 'pallas' — the local conv kernel (see _conv_nhwc).
    """
    x = cast_to_weight_dtype(x, w)   # the repo-wide mixed-precision rule
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    k_h, k_w = w.shape[0], w.shape[1]
    if p <= 1:
        if sharding.is_spatial:
            # a size-1 CF group with live spatial axes is just spatial
            # parallelism — route to the halo-exchange runtime.
            return spatial_conv2d(
                x, w, strides=strides,
                sharding=ConvSharding(batch_axes=sharding.batch_axes,
                                      h_axis=sharding.h_axis,
                                      w_axis=sharding.w_axis),
                mesh=mesh, overlap=overlap, backend=backend)
        # dense fallback — the 1x1-mesh oracle path, bitwise-identical.
        return _conv_nhwc(x, w, strides,
                          (same_pads(k_h, strides[0]),
                           same_pads(k_w, strides[1])), backend)
    c, f = w.shape[2], w.shape[3]
    if c % p or f % p:
        # hard error, not an assert: under `python -O` a stripped assert
        # would let _slice_block truncate the channel sum silently
        raise ValueError(
            f"channels C={c}, F={f} not divisible by {p}-way CF axis "
            f"{sharding.cf_axis!r} — core.plan demotes such layers at "
            "compile time; direct callers must pre-check "
            "CFSharding.fits_channels")
    if channel_chunks is None:
        channel_chunks = default_channel_chunks()
    fn = functools.partial(_local_cf_conv, strides=strides,
                           sharding=sharding, mesh_shape=mesh_shape,
                           overlap=overlap, backend=backend,
                           channel_chunks=channel_chunks)
    spec = sharding.x_spec()
    # one repo-wide replication policy per backend (utils.replication_policy;
    # the static auditor reports which policy each region compiled under)
    policy = replication_policy(backend)
    return shard_map(fn, mesh=mesh, in_specs=(spec, P()), out_specs=spec,
                     legacy_check_rep=policy.legacy_check_rep)(x, w)


def cf_bias_add(x, b, *, sharding: CFSharding, mesh=None):
    """Add a per-channel bias to a C-sharded NHWC tensor (b stays global)."""
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    if p <= 1:
        return x + b
    spec = sharding.x_spec()

    def fn(x, b):
        return x + _slice_block(b, sharding.cf_axis, p, dim=0)

    return shard_map(fn, mesh=mesh, in_specs=(spec, P()),
                     out_specs=spec)(x, b)


def cf_batch_norm(x, gamma, beta, *, sharding: CFSharding, mesh=None,
                  scope: str = "local", eps: float = 1e-5):
    """BN over (N, H, W) of a C-sharded NHWC tensor.

    Per-channel statistics never cross the CF axis (each channel lives on
    exactly one shard of the CF group), so with no composed spatial axes
    'local' and 'spatial' scopes are communication-free and 'global' psums
    the moments over the batch axes only.  Under CF x spatial composition a
    channel's rows DO cross the spatial axes, so 'spatial'/'global' scopes
    psum over them too — same aggregation as core.spatial_norm.  gamma/beta
    stay globally addressed, sliced per shard like the conv weights.
    """
    if scope not in ("local", "spatial", "global"):
        raise ValueError(f"unknown BN scope {scope!r}")
    mesh = _resolve_mesh(mesh)
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    p = mesh_shape.get(sharding.cf_axis, 1) if sharding.cf_axis else 1
    stat_axes = ()
    if scope in ("spatial", "global"):
        stat_axes += sharding.spatial_axes
    if scope == "global":
        stat_axes += tuple(sharding.batch_axes or ())
    comm_axes = tuple(a for a in stat_axes if mesh_shape.get(a, 1) > 1)
    if p <= 1 and not comm_axes:
        # dense fallback, formulated exactly like core.spatial_norm's local
        # path so the 1x1-mesh numerics are bitwise-identical
        xf = x.astype(jnp.float32)
        n = x.shape[0] * x.shape[1] * x.shape[2]
        mean = jnp.sum(xf, (0, 1, 2)) / n
        var = jnp.sum(jnp.square(xf), (0, 1, 2)) / n - jnp.square(mean)
        inv = lax.rsqrt(var + eps)
        y = (x - mean.astype(x.dtype)) * inv.astype(x.dtype)
        return y * gamma + beta

    def fn(x, g, b):
        xf = x.astype(jnp.float32)
        s = jnp.sum(xf, (0, 1, 2))
        ss = jnp.sum(jnp.square(xf), (0, 1, 2))
        n = x.shape[0] * x.shape[1] * x.shape[2]
        if comm_axes:
            with trace_lib.annotate("bn_collective"):
                s = lax.psum(s, comm_axes)
                ss = lax.psum(ss, comm_axes)
            for a in comm_axes:
                n *= mesh_shape[a]
        mean = s / n
        var = ss / n - jnp.square(mean)
        inv = lax.rsqrt(var + eps)
        y = (x - mean.astype(x.dtype)) * inv.astype(x.dtype)
        if p > 1:
            g = _slice_block(g, sharding.cf_axis, p, dim=0)
            b = _slice_block(b, sharding.cf_axis, p, dim=0)
        return y * g + b

    spec = sharding.x_spec()
    return shard_map(fn, mesh=mesh, in_specs=(spec, P(), P()),
                     out_specs=spec)(x, gamma, beta)
