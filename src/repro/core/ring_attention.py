"""Sequence-parallel exact attention — the paper's spatial decomposition
applied to the transformer sequence dimension.

The activation tensors are block-partitioned along the sequence (the
"spatial" dimension of a transformer); each shard holds a Q/K/V block.  The
"halo" a query block needs is its causal past:

  * full/global attention   — the halo spans every predecessor shard, so the
    K/V blocks sweep the ring (`ppermute` per step) while an online-softmax
    accumulator merges partial results (ring attention).  Cost = (P-1)
    neighbor exchanges of the local K/V block — the paper's SR(·) halo terms
    with the block as the halo.

  * sliding-window attention (mixtral SWA, gemma2 local layers, hymba) — a
    query needs at most `window` past keys, i.e. a *constant-width halo* of
    ceil((window-1)/S_local) predecessor blocks.  This is the literal
    transformer instantiation of the paper's O-row conv halo: the ring stops
    after n_steps = 1 + that many exchanges instead of P.

  * bidirectional (encoders) — full ring sweep, no causal mask.

Exactness: results equal single-device attention up to fp accumulation
(verified in tests), mirroring the paper's exact-replication requirement.

Everything here runs *inside* shard_map over the sequence axis; the public
wrapper builds the shard_map.  bf16 inputs accumulate in fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.utils import cdiv, pcast_varying, shard_map

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


def _softcap(logits, cap):
    return cap * jnp.tanh(logits / cap) if cap else logits


def _block_attend(q, k, v, *, q_off, k_off, scale, causal, window, softcap,
                  m, l, o):
    """One (Q-block, KV-block) tile of online-softmax attention.

    q: (B, Sq, Hq, D)   k, v: (B, Sk, Hkv, D)   GQA via head grouping.
    m, l: (B, Hq, Sq)   o: (B, Sq, Hq, D) accumulators (fp32).
    q_off/k_off: global offsets of the blocks (for causal/window masks).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)

    qpos = q_off + jnp.arange(sq)[:, None]
    kpos = k_off + jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    s = s.reshape(b, hq, sq, k.shape[1])

    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bqhgd",
                    p.reshape(b, hkv, g, sq, k.shape[1]),
                    v.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    o_new = o * corr.transpose(0, 2, 1)[..., None] + pv.reshape(b, sq, hq, d)
    return m_new, l_new, o_new


def _ring_attention_local(q, k, v, *, axis_name, axis_size, vma_axes, scale,
                          causal, window, softcap, unroll=False):
    """Shard-local ring attention (inside shard_map over the seq axis)."""
    b, sl, hq, d = q.shape
    idx = lax.axis_index(axis_name)
    q_off = idx * sl

    if window is None:
        n_steps = axis_size
    else:
        n_steps = min(axis_size, 1 + cdiv(max(window - 1, 0), sl))

    def var(x):  # mark device-varying for shard_map's VMA tracking
        return pcast_varying(x, vma_axes)

    m = var(jnp.full((b, hq, sl), NEG_INF, jnp.float32))
    l = var(jnp.zeros((b, hq, sl), jnp.float32))
    o = var(jnp.zeros((b, sl, hq, d), jnp.float32))
    kv = jnp.concatenate([k, v], axis=-1)

    def step(carry, t):
        kv, m, l, o = carry
        src = (idx - t) % axis_size  # which shard's KV we currently hold
        k_t, v_t = jnp.split(kv, 2, axis=-1)
        m2, l2, o2 = _block_attend(
            q, k_t, v_t, q_off=q_off, k_off=src * sl, scale=scale,
            causal=causal, window=window, softcap=softcap, m=m, l=l, o=o)
        if causal:
            # shards strictly after us contribute nothing; skip their update
            # (the tile was fully masked anyway — this keeps l exact at 0+).
            use = src <= idx
            m, l, o = jax.tree.map(
                lambda new, old: jnp.where(use, new, old),
                (m2, l2, o2), (m, l, o))
        else:
            m, l, o = m2, l2, o2
        # rotate KV: shard i sends to i+1 so next step we hold (idx - t - 1)'s
        kv = lax.ppermute(
            kv, axis_name,
            [(i, (i + 1) % axis_size) for i in range(axis_size)])
        return (kv, m, l, o), None

    # remat each ring step: the scan's backward otherwise saves the fp32
    # attention probabilities of EVERY step (n_steps x B x Hq x Sl x Sl —
    # 16 GiB/device for gemma2 train_4k); recomputing them per step in the
    # backward sweep is the standard flash/ring-attention trade.
    (kv, m, l, o), _ = lax.scan(jax.checkpoint(step), (kv, m, l, o),
                                jnp.arange(n_steps),
                                unroll=n_steps if unroll else 1)
    out = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def ring_attention(q, k, v, *, mesh, seq_axis: str | None, scale=None,
                   causal: bool = True, window: int | None = None,
                   softcap: float | None = None, batch_axes=("data",),
                   unroll: bool = False):
    """Exact attention with sequence sharded over `seq_axis`.

    q: (B, S, Hq, D), k/v: (B, S, Hkv, D) — S block-partitioned on seq_axis,
    B on batch_axes.  seq_axis=None falls back to single-shard attention
    (used as the oracle and for unsharded configs).
    """
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if seq_axis is None:
        m = jnp.full(q.shape[:1] + (q.shape[2], q.shape[1]), NEG_INF,
                     jnp.float32)
        l = jnp.zeros_like(m)
        o = jnp.zeros(q.shape[:3] + (v.shape[-1],), jnp.float32)
        m, l, o = _block_attend(q, k, v, q_off=0, k_off=0, scale=scale,
                                causal=causal, window=window, softcap=softcap,
                                m=m, l=l, o=o)
        return (o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
                ).astype(q.dtype)

    axis_size = dict(mesh.shape)[seq_axis]
    vma_axes = tuple(batch_axes) + (seq_axis,)
    fn = functools.partial(
        _ring_attention_local, axis_name=seq_axis, axis_size=axis_size,
        vma_axes=vma_axes, scale=scale, causal=causal, window=window,
        softcap=softcap, unroll=unroll)
    bspec = tuple(batch_axes) or None
    spec = P(bspec, seq_axis, None, None)
    # ppermute-only body, sharded outputs: gradient-safe without legacy
    # replication tracking (which cannot transpose the ring scan).
    return shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, legacy_check_rep=False)(q, k, v)
