"""Parallel execution strategies (paper §V-C).

Given a network (DAG of layers), a machine and a mesh, pick a distribution
for every layer:

  1. generate per-layer candidate distributions — load-balanced assignments
     of mesh axes to tensor dimensions, preferring cheaper methods (sample
     over spatial over channel/filter) exactly as the paper's heuristic;
  2. line networks: single-source shortest path over the layered DAG whose
     edge (D_i at ℓ_i) -> (D_j at ℓ_{i+1}) costs Cost_{D_i}(ℓ_i) +
     Shuffle(D_i, D_j); solved by DP in topological order (linear time);
  3. branchy networks (ResNets): longest-path-first — fix the most
     compute-intensive source-to-sink path with (2), then repeat on the next
     longest path containing the fewest already-fixed layers, inheriting
     fixed layers as forced single candidates, until all layers are covered.

Channel/filter parallelism — sketched-only in the paper (§III-D) — is a
selectable candidate here (beyond-paper), so the optimizer can discover it
for many-filter/small-spatial layers.

Every edge cost flows through perfmodel.layer_cost, so the §IV-A overlap
credit the solver optimizes against is η-scaled: a machine whose calibrated
``overlap_eta`` < 1 credits halo/CF hiding only to the degree the A/B
microbenchmark measured it, which can flip the optimum away from
communication-heavy distributions that only pay under perfect overlap.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

import networkx as nx

from repro.core.distribution import Dist
from repro.core.perfmodel import (ConvLayer, EmpiricalTable, Machine,
                                  layer_cost, layer_memory, shuffle_time)
from repro.utils import human_bytes


class CapacityError(ValueError):
    """No candidate distribution of some layer fits the per-device memory
    limit.  Follows core.plan.PlanError's diagnostics discipline: messages
    name the layer and report its smallest-achievable footprint, which
    distribution achieves it, and the footprint breakdown — so users can
    see whether the wall is weights, activations, halo or gradients."""


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------

def prune_by_memory(m: Machine, layer: ConvLayer,
                    candidates: Sequence[Dist],
                    mesh_shape: Mapping[str, int],
                    mem_limit: float | None,
                    opt_words: float = 1.0) -> list[Dist]:
    """Drop candidate dists whose per-layer resident set exceeds
    `mem_limit` bytes/device (perfmodel.layer_memory) — the capacity
    constraint of the memory-aware solve.  Raises CapacityError when
    *nothing* fits, naming the layer and the smallest-achievable footprint
    (this is how the paper's 'unreachable' workloads surface: sample
    parallelism cannot reduce per-device activations below one sample)."""
    if not mem_limit or mem_limit <= 0:
        return list(candidates)
    mems = [(layer_memory(m, layer, d, mesh_shape, opt_words), d)
            for d in candidates]
    kept = [d for lm, d in mems if lm.total <= mem_limit]
    if not kept:
        best_mem, best = min(mems, key=lambda md: md[0].total)
        raise CapacityError(
            f"layer {layer.name!r}: no candidate distribution fits the "
            f"{human_bytes(mem_limit)}/device memory limit; smallest "
            f"achievable footprint is {human_bytes(best_mem.total)} "
            f"under dist {best.name!r} ({best_mem.breakdown()})")
    return kept


def candidate_dists(layer: ConvLayer, mesh_shape: Mapping[str, int],
                    allow_channel_filter: bool = False,
                    allow_w_split: bool = True,
                    wide: bool = False) -> list[Dist]:
    """Load-balanced assignments of every mesh axis to one tensor dim.

    Each mesh axis independently partitions one of N / H / W / (C&F); an
    assignment is valid iff every dim divides evenly and spatial shards stay
    at least kernel-sized (the paper's edge case).  Ordered cheapest-first
    (sample < spatial < channel/filter) so ties break toward the paper's
    preference.

    `wide` (the --search beam/hillclimb space, per Jia et al. 1802.04924)
    additionally lets a mesh axis go *unassigned* ("R": the layer replicates
    over it) — a strict superset of the default space, so a wide solve's
    predicted optimum is never worse than the greedy one's.
    """
    axes = list(mesh_shape)
    targets = ["N", "H"]
    if allow_w_split:
        targets.append("W")
    if allow_channel_filter and layer.kind == "conv":
        targets.append("CF")
    if wide:
        targets.append("R")

    def rank(assign):  # cheaper methods first
        order = {"N": 0, "H": 1, "W": 1, "CF": 2, "R": 3}
        return tuple(sorted(order[t] for t in assign))

    seen, out = set(), []
    for assign in sorted(itertools.product(targets, repeat=len(axes)),
                         key=rank):
        dims: dict[str, tuple[str, ...]] = {}
        for ax, tgt in zip(axes, assign):
            if tgt == "R":      # axis left unassigned: replicate over it
                continue
            for d in (("C", "F") if tgt == "CF" else (tgt,)):
                dims[d] = dims.get(d, ()) + (ax,)
        d = Dist("+".join(sorted(set(assign))).lower(), dims)
        ways = {k: d.ways(k, mesh_shape) for k in ("N", "H", "W", "C", "F")}
        if layer.n % ways["N"] or layer.h % ways["H"] or \
           layer.w % ways["W"] or layer.c % ways["C"] or layer.f % ways["F"]:
            continue
        if ways["H"] > 1 and layer.h // ways["H"] < layer.k:
            continue
        if ways["W"] > 1 and layer.w // ways["W"] < layer.k:
            continue
        if layer.kind == "pool" and (ways["C"] > 1 or ways["F"] > 1):
            continue
        key = tuple(sorted((k, v) for k, v in dims.items()))
        if key in seen:
            continue
        seen.add(key)
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# line-network shortest path (paper §V-C)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StrategyResult:
    dists: list[Dist]
    cost: float


def solve_line(m: Machine, layers: Sequence[ConvLayer],
               candidates: Sequence[Sequence[Dist]],
               mesh_shape: Mapping[str, int],
               table: EmpiricalTable | None = None,
               overlap: bool = True,
               mem_limit: float | None = None,
               opt_words: float = 1.0) -> StrategyResult:
    """DP shortest path over the candidate-distribution DAG.

    With `mem_limit` (bytes/device) the solve is min-time *subject to*
    every layer's resident set fitting: infeasible dists are pruned from
    the candidate sets (prune_by_memory), and a layer with no fitting
    candidate raises CapacityError with its footprint diagnostics.
    """
    n = len(layers)
    assert n and all(candidates), "every layer needs >= 1 candidate"
    if mem_limit:
        candidates = [prune_by_memory(m, layers[i], candidates[i],
                                      mesh_shape, mem_limit, opt_words)
                      for i in range(n)]
    lcost = [[layer_cost(m, layers[i], d, mesh_shape, table, overlap).total
              for d in candidates[i]] for i in range(n)]

    best = list(lcost[0])                      # source -> first-layer nodes
    back: list[list[int]] = [[-1] * len(candidates[0])]
    for i in range(1, n):
        cur = []
        bk = []
        for j, dj in enumerate(candidates[i]):
            best_prev, arg = float("inf"), -1
            for p, dp in enumerate(candidates[i - 1]):
                w = best[p] + shuffle_time(m, layers[i - 1], dp, dj,
                                           mesh_shape, table)
                if w < best_prev:
                    best_prev, arg = w, p
            cur.append(best_prev + lcost[i][j])
            bk.append(arg)
        best, back = cur, back + [bk]

    j = min(range(len(best)), key=best.__getitem__)
    total = best[j]
    picks = [j]
    for i in range(n - 1, 0, -1):
        j = back[i][j]
        picks.append(j)
    picks.reverse()
    return StrategyResult([candidates[i][picks[i]] for i in range(n)], total)


# ---------------------------------------------------------------------------
# branchy networks: longest-path-first (paper §V-C)
# ---------------------------------------------------------------------------

def solve_dag(m: Machine, graph: nx.DiGraph,
              mesh_shape: Mapping[str, int],
              table: EmpiricalTable | None = None,
              overlap: bool = True,
              allow_channel_filter: bool = False,
              candidate_fn=None,
              mem_limit: float | None = None,
              opt_words: float = 1.0) -> dict[str, Dist]:
    """graph: DiGraph whose nodes carry a 'layer': ConvLayer attribute.

    `candidate_fn(layer) -> [Dist]` overrides the default candidate
    generation — the plan compiler (core.plan) uses it to restrict the search
    to distributions the runtime can execute.  `mem_limit` applies the
    per-device capacity constraint to every path solve (see solve_line).

    Returns {layer name: Dist}.
    """
    assert nx.is_directed_acyclic_graph(graph)
    if candidate_fn is None:
        candidate_fn = lambda l: candidate_dists(  # noqa: E731
            l, mesh_shape, allow_channel_filter=allow_channel_filter)
    fixed: dict[str, Dist] = {}
    g = graph.copy()
    for u, v in g.edges:
        g[u][v]["w"] = g.nodes[u]["layer"].flops_fwd()

    while len(fixed) < graph.number_of_nodes():
        # longest (most compute-intensive) path among unfixed-containing ones
        path = nx.dag_longest_path(g, weight="w")
        if all(p in fixed for p in path):
            # fall back: any unfixed node, treated as a singleton path
            path = [next(n for n in g.nodes if n not in fixed)]
        layers = [graph.nodes[p]["layer"] for p in path]
        cands = [[fixed[p]] if p in fixed else candidate_fn(layers[i])
                 for i, p in enumerate(path)]
        res = solve_line(m, layers, cands, mesh_shape, table, overlap,
                         mem_limit=mem_limit, opt_words=opt_words)
        for p, d in zip(path, res.dists):
            fixed.setdefault(p, d)
        # de-prioritize the fixed path so the next longest path is found
        for u, v in zip(path, path[1:]):
            if g.has_edge(u, v):
                g[u][v]["w"] = 0.0
    return fixed


# ---------------------------------------------------------------------------
# global search (beyond-paper: Jia et al. 1802.04924): reshard-cost-aware
# beam DP over the whole DAG, and a stochastic hill-climbing baseline
# ---------------------------------------------------------------------------

def solve_dag_beam(m: Machine, graph: nx.DiGraph,
                   mesh_shape: Mapping[str, int],
                   table: EmpiricalTable | None = None,
                   overlap: bool = True,
                   allow_channel_filter: bool = False,
                   candidate_fn=None,
                   mem_limit: float | None = None,
                   opt_words: float = 1.0,
                   width: int = 4) -> dict[str, Dist]:
    """Global beam-searched DP over the *whole* DAG in topological order.

    Unlike longest-path-first (solve_dag), which zeroes already-fixed path
    edges and so never re-prices the cross edges between paths, every beam
    state here carries a full partial assignment and each extension pays the
    shuffle cost on *every* incoming DAG edge.  `width` beam states survive
    per layer; width -> inf is the exact (exponential) DP.

    Returns {layer name: Dist}.
    """
    assert nx.is_directed_acyclic_graph(graph)
    if candidate_fn is None:
        candidate_fn = lambda l: candidate_dists(  # noqa: E731
            l, mesh_shape, allow_channel_filter=allow_channel_filter,
            wide=True)
    order = list(nx.topological_sort(graph))
    pos = {name: i for i, name in enumerate(order)}
    layers = [graph.nodes[p]["layer"] for p in order]
    cands: list[list[Dist]] = []
    for lay in layers:
        cs = list(candidate_fn(lay))
        if mem_limit:
            cs = prune_by_memory(m, lay, cs, mesh_shape, mem_limit,
                                 opt_words)
        cands.append(cs)
    lcost = [[layer_cost(m, layers[i], d, mesh_shape, table, overlap).total
              for d in cands[i]] for i in range(len(order))]
    preds = [[pos[u] for u in graph.predecessors(p)] for p in order]

    # beam state: (cost, (dist index per already-placed layer, ...))
    beam: list[tuple[float, tuple[int, ...]]] = [(0.0, ())]
    for i in range(len(order)):
        nxt = []
        for cost, picks in beam:
            for j, dj in enumerate(cands[i]):
                w = cost + lcost[i][j]
                for u in preds[i]:
                    w += shuffle_time(m, layers[u], cands[u][picks[u]], dj,
                                      mesh_shape, table)
                nxt.append((w, picks + (j,)))
        nxt.sort(key=lambda s: s[0])
        beam = nxt[:max(width, 1)]
    _, picks = beam[0]
    return {order[i]: cands[i][picks[i]] for i in range(len(order))}


def solve_hillclimb(m: Machine, layers: Sequence[ConvLayer],
                    candidates: Sequence[Sequence[Dist]],
                    mesh_shape: Mapping[str, int],
                    table: EmpiricalTable | None = None,
                    overlap: bool = True,
                    edges: Sequence[tuple[int, int]] | None = None,
                    seed: int = 0,
                    iters: int = 400,
                    restarts: int = 4,
                    mem_limit: float | None = None,
                    opt_words: float = 1.0) -> StrategyResult:
    """Stochastic local-search baseline (the rebuilt benchmarks/hillclimb):
    random restarts + single-layer moves accepted when they lower the total
    predicted cost.  `edges` are (i, j) index pairs that pay Shuffle(D_i,
    D_j) on ℓ_i's output; None means the line network's consecutive pairs.
    Deterministic under `seed`.
    """
    import random
    n = len(layers)
    assert n and all(candidates), "every layer needs >= 1 candidate"
    if mem_limit:
        candidates = [prune_by_memory(m, layers[i], candidates[i],
                                      mesh_shape, mem_limit, opt_words)
                      for i in range(n)]
    if edges is None:
        edges = [(i, i + 1) for i in range(n - 1)]
    touching = [[] for _ in range(n)]
    for e in edges:
        touching[e[0]].append(e)
        touching[e[1]].append(e)
    lcost = [[layer_cost(m, layers[i], d, mesh_shape, table, overlap).total
              for d in candidates[i]] for i in range(n)]
    shuf_memo: dict[tuple, float] = {}

    def edge_cost(picks, e):
        i, j = e
        key = (i, j, picks[i], picks[j])
        t = shuf_memo.get(key)
        if t is None:
            t = shuffle_time(m, layers[i], candidates[i][picks[i]],
                             candidates[j][picks[j]], mesh_shape, table)
            shuf_memo[key] = t
        return t

    def total(picks):
        return sum(lcost[i][picks[i]] for i in range(n)) + \
            sum(edge_cost(picks, e) for e in edges)

    rng = random.Random(seed)
    best_picks, best_cost = None, float("inf")
    for _ in range(max(restarts, 1)):
        picks = [rng.randrange(len(candidates[i])) for i in range(n)]
        cost = total(picks)
        for _ in range(iters):
            i = rng.randrange(n)
            if len(candidates[i]) < 2:
                continue
            j = rng.randrange(len(candidates[i]))
            if j == picks[i]:
                continue
            old = picks[i]
            delta = lcost[i][j] - lcost[i][old]
            before = sum(edge_cost(picks, e) for e in touching[i])
            picks[i] = j
            after = sum(edge_cost(picks, e) for e in touching[i])
            delta += after - before
            if delta < 0:
                cost += delta
            else:
                picks[i] = old
        if cost < best_cost:
            best_cost, best_picks = cost, list(picks)
    return StrategyResult([candidates[i][best_picks[i]] for i in range(n)],
                          best_cost)


def parse_search(spec: str) -> tuple[str, int]:
    """'greedy' | 'beam[:N]' | 'hillclimb' -> (mode, beam width)."""
    s = (spec or "greedy").strip().lower()
    if s == "greedy":
        return "greedy", 0
    if s == "hillclimb":
        return "hillclimb", 0
    if s == "beam":
        return "beam", 4
    if s.startswith("beam:"):
        try:
            w = int(s.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad beam width in --search {spec!r}")
        if w < 1:
            raise ValueError(f"beam width must be >= 1, got {w}")
        return "beam", w
    raise ValueError(
        f"unknown search mode {spec!r} (expected greedy, beam[:N] or "
        f"hillclimb)")
