"""Distribution descriptors — the paper's D = (D^(0), ..., D^(M-1)) notation
(§II-C) as concrete objects shared by the perf model, the strategy optimizer
and the runtime sharding rules.

A `Dist` maps each *logical* tensor dimension of a layer to the mesh axes
that partition it (empty tuple = replicated).  CNN layers use dims
N/H/W/C/F; transformer blocks use N/S (sequence) /HEADS/FFN/EXPERTS/VOCAB.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Dist:
    name: str
    dims: Mapping[str, tuple[str, ...]]   # logical dim -> mesh axes

    def axes(self, dim: str) -> tuple[str, ...]:
        return tuple(self.dims.get(dim, ()))

    def ways(self, dim: str, mesh_shape: Mapping[str, int]) -> int:
        w = 1
        for a in self.axes(dim):
            w *= mesh_shape[a]
        return w

    def spec(self, *dims: str) -> P:
        """PartitionSpec for a tensor whose dims are the given logical dims
        ('_' = replicated dimension)."""
        return P(*[(self.axes(d) or None) if d != "_" else None
                   for d in dims])

    def local(self, dim: str, size: int, mesh_shape) -> int:
        w = self.ways(dim, mesh_shape)
        assert size % w == 0, f"{dim}={size} not divisible by {w} ({self.name})"
        return size // w

    def same_as(self, other: "Dist") -> bool:
        keys = set(self.dims) | set(other.dims)
        return all(self.axes(k) == other.axes(k) for k in keys)


# --- canonical CNN strategies (paper §III) --------------------------------
def sample(batch_axes=("data",)) -> Dist:
    return Dist("sample", {"N": tuple(batch_axes)})


def spatial(h_axes=("model",), batch_axes=()) -> Dist:
    return Dist("spatial", {"N": tuple(batch_axes), "H": tuple(h_axes)})


def hybrid(batch_axes=("data",), h_axes=("model",)) -> Dist:
    return Dist("hybrid", {"N": tuple(batch_axes), "H": tuple(h_axes)})


def channel_filter(cf_axes=("model",), batch_axes=("data",)) -> Dist:
    """Paper §III-D (sketched there, implemented here as a beyond-paper
    feature): C of the input and F of the output partitioned."""
    return Dist("channel_filter",
                {"N": tuple(batch_axes), "C": tuple(cf_axes),
                 "F": tuple(cf_axes)})


# --- canonical transformer strategies -------------------------------------
def seq_parallel(batch_axes=("data",), seq_axes=("model",)) -> Dist:
    """The paper's spatial parallelism on the sequence dimension."""
    return Dist("seq_parallel", {"N": tuple(batch_axes),
                                 "S": tuple(seq_axes)})


def tensor_parallel(batch_axes=("data",), tp_axes=("model",)) -> Dist:
    """Channel/filter parallelism on heads/ffn (paper §III-D analogue)."""
    return Dist("tensor_parallel", {"N": tuple(batch_axes),
                                    "HEADS": tuple(tp_axes),
                                    "FFN": tuple(tp_axes)})


def expert_parallel(batch_axes=("data",), ep_axes=("model",)) -> Dist:
    return Dist("expert_parallel", {"N": tuple(batch_axes),
                                    "EXPERTS": tuple(ep_axes)})
