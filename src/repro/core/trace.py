"""Plan-aware tracing & attribution (the observability half of the §V loop).

The perf model *prices* every §III distribution; this module makes the
runtime *attribute* where a measured step actually spends its time, so the
model-vs-measured comparison decomposes per layer and per cost term instead
of being one opaque end-to-end ratio.

Two mechanisms:

  * **Named-region annotation** — `annotate(region)` wraps a stretch of
    traced code in ``jax.named_scope`` (the name lands in the compiled
    HLO's ``op_name`` metadata, so XLA profiles and `compiled.as_text()`
    are decodable) plus ``jax.profiler.TraceAnnotation`` (host-side
    profiler timelines).  `layer_context(name)` pushes the current layer
    name so every region inside an execution path is keyed by the layer
    that ran it — the paths thread it through halo exchange
    (core.halo), interior/boundary conv (core.spatial_conv), the CF
    collectives and BN psums (core.channel_conv) and §III-C reshard
    points (core.plan).  Annotation is identity on values: it never
    changes numerics or op order, only metadata.

  * **Segmented re-execution profiling** — `trace_plan(plan, params,
    batch)` AOT-compiles each plan layer's forward and forward+backward
    in isolation (the real per-layer callables from
    models.cnn.meshnet.layer_fns, fed the real intermediate activations
    captured from one forward pass, each under its plan sharding) and
    times them with the repo's interleaved-rounds discipline
    (utils.interleaved_min — the same estimator benchmarks/strategy_exec
    uses), producing a `StepTrace` of measured per-layer fwd/bwd seconds
    next to the whole-step time, with JSON round-trip and Chrome-trace
    export (load the file in chrome://tracing or Perfetto).

`NetworkPlan.attribution_report(trace)` (core.plan) joins a StepTrace
against the `layer_cost`/`layer_memory` predictions into the per-layer
predicted-vs-measured table; `format_attribution` renders it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Mapping

import jax

SCHEMA = "repro/step_trace@1"

# ---------------------------------------------------------------------------
# named-region annotation
# ---------------------------------------------------------------------------

#: Canonical region names every execution path annotates with — the closed
#: vocabulary the static collective auditor (repro.analysis.collectives)
#: keys its jaxpr/StableHLO attribution on.  Adding a region to an
#: execution path means adding it here, or the auditor cannot attribute
#: its collectives to a cost term.
REGIONS = (
    "halo_exchange",      # spatial ppermute halos (core.halo)
    "conv_interior",      # overlap-pinned interior conv (core.spatial_conv)
    "conv_boundary",      # boundary strips after the halo arrives
    "conv_serialized",    # non-overlapped halo+conv fallback
    "cf_all_gather",      # CF filter-mode x gather (core.channel_conv)
    "cf_reduce_scatter",  # CF channel-mode y scatter
    "bn_collective",      # BN stats psums (core.spatial_norm)
    "reshard",            # §III-C reshard points (core.plan)
)

_LAYER_STACK: list[str] = []


def current_layer() -> str | None:
    """The innermost active `layer_context` name, or None outside one."""
    return _LAYER_STACK[-1] if _LAYER_STACK else None


@contextlib.contextmanager
def layer_context(name: str):
    """Key every region traced inside with layer `name`.

    Opens a ``jax.named_scope(name)`` so all ops of the layer carry the
    layer name in their HLO ``op_name`` path, and pushes `name` onto the
    layer stack that `annotate`/`current_layer` read — which is also how
    --debug-nans and error paths name the offending layer.
    """
    _LAYER_STACK.append(name)
    try:
        with jax.named_scope(name):
            yield
    finally:
        _LAYER_STACK.pop()


def qualified(region: str) -> str:
    """`region` prefixed with the current layer name, when one is set."""
    layer = current_layer()
    return f"{layer}/{region}" if layer else region


@contextlib.contextmanager
def annotate(region: str):
    """Mark a named region of traced code; identity on values.

    Inside jit tracing the ``jax.named_scope`` lands `region` in the
    compiled HLO op_name metadata (nested under any `layer_context`), so
    XLA profiles decode to plan terms; the
    ``jax.profiler.TraceAnnotation`` additionally marks host-side
    profiler timelines when a profiler session is active (it is a no-op
    otherwise, and absent on backends without it).
    """
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    with contextlib.ExitStack() as st:
        st.enter_context(jax.named_scope(region))
        if ta is not None:
            st.enter_context(ta(qualified(region)))
        yield


# ---------------------------------------------------------------------------
# StepTrace — measured per-layer costs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepTrace:
    """Measured per-layer cost breakdown of one training step.

    layers: {layer name: {"fwd_s", "bwd_s", "fwd_bwd_s"}} in execution
            order — seconds per call of the layer's isolated AOT-compiled
            forward / forward+backward.
    step:   {"fwd_s", "bwd_s", "fwd_bwd_s"} of the WHOLE fused step (the
            same estimator), the cross-check target: the per-layer sums
            should land within dispatch-overhead tolerance of it.
    meta:   backend, mesh shape, device count, timing reps/rounds,
            measured peak bytes (XLA memory_analysis), overlap flag and
            the calibrated achieved-overlap η in force (when measured).
    """
    layers: dict[str, dict]
    step: dict[str, float]
    meta: dict = dataclasses.field(default_factory=dict)
    schema: str = SCHEMA

    # -- derived ------------------------------------------------------------
    @property
    def layer_fwd_sum_s(self) -> float:
        return sum(r["fwd_s"] for r in self.layers.values())

    @property
    def layer_bwd_sum_s(self) -> float:
        return sum(r["bwd_s"] for r in self.layers.values())

    @property
    def layer_sum_s(self) -> float:
        """Sum of isolated per-layer fwd+bwd times — compare to
        step['fwd_bwd_s'] to bound the segmentation overhead."""
        return self.layer_fwd_sum_s + self.layer_bwd_sum_s

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": self.schema, "layers": self.layers,
                "step": self.step, "meta": self.meta}

    @classmethod
    def from_dict(cls, d: Mapping) -> "StepTrace":
        if d.get("schema") != SCHEMA:
            raise ValueError(f"not a step trace: schema "
                             f"{d.get('schema')!r} != {SCHEMA!r}")
        return cls(layers=dict(d["layers"]), step=dict(d["step"]),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "StepTrace":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- Chrome-trace export ------------------------------------------------
    def chrome_trace(self) -> dict:
        """The measured breakdown as a Chrome-trace / Perfetto JSON object.

        Forward segments lie on one track in execution order, backward
        segments on a second track in reverse (backprop) order, laid out
        end to end from their measured durations — a synthetic but
        to-scale timeline of where the step's time goes.  Timestamps and
        durations are microseconds, per the trace-event spec.
        """
        events = [
            {"ph": "M", "pid": 0, "name": "process_name",
             "args": {"name": "repro step trace"}},
            {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
             "args": {"name": "forward"}},
            {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name",
             "args": {"name": "backward"}},
        ]
        ts = 0.0
        for name, r in self.layers.items():
            dur = r["fwd_s"] * 1e6
            events.append({"ph": "X", "pid": 0, "tid": 0, "name": name,
                           "cat": "fwd", "ts": ts, "dur": dur})
            ts += dur
        for name, r in reversed(list(self.layers.items())):
            dur = r["bwd_s"] * 1e6
            events.append({"ph": "X", "pid": 0, "tid": 1, "name": name,
                           "cat": "bwd", "ts": ts, "dur": dur})
            ts += dur
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": dict(self.meta, schema=self.schema)}

    def save_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)


# ---------------------------------------------------------------------------
# segmented re-execution profiler
# ---------------------------------------------------------------------------

def trace_plan(plan, params, batch, *, cfg, mesh, overlap: bool = True,
               reps: int = 3, rounds: int = 3) -> StepTrace:
    """Measure every plan layer's fwd/bwd cost by isolated re-execution.

    plan:   a core.plan.NetworkPlan (or anything NetworkPlan.of accepts).
    params: the model parameter list (models.cnn.meshnet layout).
    batch:  {"image", "label"} device arrays, image sharded per the plan's
            first-layer input spec.
    cfg:    the MeshNetConfig the plan was solved for.

    One forward pass captures the real intermediate activation entering
    each layer (each under the sharding the plan's reshard points leave it
    in), then each layer's callable (meshnet.layer_fns — the exact code
    `apply` runs, §III-C reshard included) is AOT-compiled standalone as
    forward and as forward+backward and timed with the interleaved-rounds
    estimator against the whole fused step, so host-load drift hits every
    segment equally.  bwd_s is (fwd+bwd) - fwd, floored at 0.
    """
    import functools

    import jax.numpy as jnp

    from repro.core.calibrate import compiled_peak_bytes
    from repro.core.channel_conv import measured_eta
    from repro.core.plan import NetworkPlan
    from repro.models.cnn import meshnet
    from repro.utils import interleaved_min

    plan = NetworkPlan.of(plan)
    fns = meshnet.layer_fns(cfg, plan, mesh, overlap)

    with mesh:
        # the whole fused step: fwd-only and fwd+bwd, AOT so the XLA
        # memory_analysis peak rides along with the timing
        fwd_step = jax.jit(lambda p, b: meshnet.apply(
            p, b["image"], cfg, plan, mesh, overlap))
        full_step = jax.jit(jax.value_and_grad(lambda p, b: meshnet.loss_fn(
            p, b, cfg, plan, mesh, overlap)))
        c_fwd = fwd_step.lower(params, batch).compile()
        c_full = full_step.lower(params, batch).compile()
        peak = compiled_peak_bytes(c_full)
        c_fwd(params, batch)[0].block_until_ready()           # warm
        jax.tree.leaves(c_full(params, batch))[0].block_until_ready()

        # capture the activation entering each layer (plan-sharded)
        def capture(p, x):
            xs = []
            for (name, fn), lp in zip(fns, p):
                xs.append(x)
                x = fn(lp, x)
            return tuple(xs)

        xs = jax.jit(capture)(params, batch["image"])

        segments = {"__step__|fwd": functools.partial(c_fwd, params, batch),
                    "__step__|fwd_bwd": functools.partial(c_full, params,
                                                          batch)}
        for (name, fn), lp, x in zip(fns, params, xs):
            c_f = jax.jit(fn).lower(lp, x).compile()

            def fwd_bwd(lp, x, fn=fn):
                return jax.value_and_grad(
                    lambda lp, x: jnp.sum(fn(lp, x)), argnums=(0, 1))(lp, x)

            c_fb = jax.jit(fwd_bwd).lower(lp, x).compile()
            c_f(lp, x).block_until_ready()                    # warm
            jax.tree.leaves(c_fb(lp, x))[0].block_until_ready()
            segments[f"{name}|fwd"] = functools.partial(c_f, lp, x)
            segments[f"{name}|fwd_bwd"] = functools.partial(c_fb, lp, x)

        times = interleaved_min(segments, reps=reps, rounds=rounds)

    layers = {}
    for name, _ in fns:
        fwd = times[f"{name}|fwd"]
        fb = times[f"{name}|fwd_bwd"]
        layers[name] = {"fwd_s": fwd, "bwd_s": max(fb - fwd, 0.0),
                        "fwd_bwd_s": fb}
    step = {"fwd_s": times["__step__|fwd"],
            "fwd_bwd_s": times["__step__|fwd_bwd"],
            "bwd_s": max(times["__step__|fwd_bwd"]
                         - times["__step__|fwd"], 0.0)}
    meta = {"backend": jax.default_backend(),
            "mesh": dict(mesh.shape),
            "ndevices": jax.device_count(),
            "reps": reps, "rounds": rounds,
            "overlap": bool(overlap),
            "overlap_eta_measured": (float(measured_eta())
                                     if measured_eta() is not None else None),
            "measured_peak_bytes": int(peak)}
    return StepTrace(layers=layers, step=step, meta=meta)


# ---------------------------------------------------------------------------
# attribution rendering
# ---------------------------------------------------------------------------

def format_attribution(report: Mapping) -> str:
    """Render a plan.attribution_report dict as the predicted-vs-measured
    table (seconds in ms; ratio = measured / predicted, >1 means slower
    than the model; flagged rows exceed the tolerance either way)."""
    rows = [f"{'layer':20s} {'pred fwd':>9s} {'meas fwd':>9s} "
            f"{'pred bwd':>9s} {'meas bwd':>9s} {'ratio':>7s}  note"]
    for name, r in report["per_layer"].items():
        flag = " <-- drift" if r["flagged"] else ""
        rows.append(
            f"{name:20s} {r['predicted_fwd_s']*1e3:8.3f}m "
            f"{r['measured_fwd_s']*1e3:8.3f}m "
            f"{r['predicted_bwd_s']*1e3:8.3f}m "
            f"{r['measured_bwd_s']*1e3:8.3f}m "
            f"{r['ratio_total']:7.2f}{flag}")
    t = report["totals"]
    rows.append(
        f"{'TOTAL':20s} {t['predicted_s']*1e3:8.3f}m "
        f"{t['measured_s']*1e3:8.3f}m   ratio "
        f"{t['ratio']:.2f}  (step measured "
        f"{t['step_measured_s']*1e3:.3f}m)")
    terms = report.get("terms", {})
    if terms:
        worst = report.get("worst_term")
        parts = [f"{k}={v['drift']:.2f}x" for k, v in terms.items()]
        rows.append(f"per-term drift (measured/predicted, "
                    f"weighted by predicted seconds): {' '.join(parts)}"
                    + (f"; worst: {worst}" if worst else ""))
    return "\n".join(rows)
