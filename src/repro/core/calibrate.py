"""Measured-cost calibration — closing the paper's §V feedback loop.

The paper's performance model is fed by *measured* primitive costs: the
authors time cuDNN kernels and MPI collectives on the target machine and
only then trust the model to rank distributions.  This module is that loop
for the live jax backend:

  1. microbenchmark the local convolution at every shard shape the strategy
     optimizer's candidate distributions would produce for the network at
     hand (forward, and the BPx data-conv shape when it differs) — these
     fill a per-shape `EmpiricalTable`, the model's first-choice lookup;
  2. microbenchmark the communication primitives at the message sizes the
     plan compiler will emit: the p2p halo exchange (one `ppermute` ring
     step — the §III-A stencil pattern) and the ring collectives
     (all-reduce / reduce-scatter / all-gather) on each mesh axis;
  3. fit the `Machine` constants from those samples: α/β for p2p and for
     the collective fabric (least squares on the linear α-β model, §II-B),
     achieved peak FLOP/s, memory bandwidth, and the compute-efficiency /
     half-performance-work pair that shapes the analytic fallback for
     table-miss shapes.

The result round-trips through JSON (`BENCH_calibration.json`) so a
calibration can be produced once (CI's bench lane, a TPU reservation) and
consumed later: `train.py --calibrate[=path]` solves `--strategy auto` on
the measured costs, and `benchmarks/strategy_exec.py` cross-checks the
calibrated predictions against measured step times.

Everything downstream already speaks the table dialect: `strategy.solve_line
/ solve_dag`, `plan.plan_line / plan_graph` and `perfmodel.network_cost`
accept `table=`; missing shapes fall back to the analytic roofline, so a
partial calibration degrades gracefully instead of failing.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import channel_conv
from repro.core.perfmodel import (LAUNCH_OVERHEAD, SHUFFLE_KIND, ConvLayer,
                                  EmpiricalTable, Machine, _halo_time,
                                  all_to_all_time, reduce_scatter_time,
                                  shuffle_block_bytes)
from repro.core.plan import executable_candidates
from repro.utils import same_pads, shard_map, time_fn

SCHEMA = "repro/calibration@1"
DEFAULT_PATH = "BENCH_calibration.json"

# starting point for constants a single-device calibration cannot fit:
# loopback-ish host comm (shared memory), overwritten whenever the mesh has
# a >1 axis to measure on.
HOST_BASE = Machine("host-base", peak_flops=1e11, mem_bw=20e9,
                    alpha=5e-6, beta=1 / 10.0e9,
                    alpha_coll=8e-6, beta_coll=1 / 10.0e9, wordsize=4,
                    compute_efficiency=1.0)


# ---------------------------------------------------------------------------
# device memory capacity + the model-vs-XLA memory cross-check
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _detect_mem_capacity(default: float = 8 << 30) -> tuple[float, str]:
    """(bytes, source) behind detect_mem_capacity / mem_capacity_source.

    Source precedence: the REPRO_MEM_CAPACITY env var (deterministic CI /
    non-Linux override, plain bytes), the live device's memory_stats
    bytes_limit, the /proc/meminfo MemAvailable share, then `default`.
    Memoized: MemAvailable jitters call-to-call, and a calibration must
    stay deterministic within a process.
    """
    env = os.environ.get("REPRO_MEM_CAPACITY")
    if env:
        try:
            cap = float(env)
            if cap > 0:
                return cap, "env:REPRO_MEM_CAPACITY"
        except ValueError:
            print(f"calibrate: WARNING: ignoring non-numeric "
                  f"REPRO_MEM_CAPACITY={env!r}")
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        stats = None
    if stats and stats.get("bytes_limit"):
        return float(stats["bytes_limit"]), "device:memory_stats"
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    kb = float(line.split()[1])
                    return (kb * 1024 / max(jax.local_device_count(), 1),
                            "host:/proc/meminfo")
    except (OSError, ValueError, IndexError):
        pass
    return float(default), "default"


def detect_mem_capacity(default: float = 8 << 30) -> float:
    """Per-device memory capacity in bytes, for Machine.mem_capacity and
    `--mem-limit auto`.

    A REPRO_MEM_CAPACITY env var (plain bytes) wins outright — the
    deterministic-capacity knob for CI and non-Linux hosts.  Otherwise
    accelerators report it directly (``jax.local_devices()[0]
    .memory_stats()['bytes_limit']``); the host CPU backend returns None
    from memory_stats, so the documented fallback divides /proc/meminfo
    MemAvailable among the (possibly xla_force_host_platform forced)
    device count — all host 'devices' share one RAM, so the per-device
    share is the honest capacity.  `default` when no source exists.
    `mem_capacity_source()` names which source answered (recorded in
    Calibration.meta)."""
    return _detect_mem_capacity(default)[0]


def mem_capacity_source(default: float = 8 << 30) -> str:
    """Which source detect_mem_capacity's answer came from."""
    return _detect_mem_capacity(default)[1]


# tests (and long-lived processes changing REPRO_MEM_CAPACITY) reset the
# memoized detection through the same knob the old lru_cached function had
detect_mem_capacity.cache_clear = _detect_mem_capacity.cache_clear


def compiled_peak_bytes(compiled) -> float:
    """Per-device peak of a compiled executable from XLA's
    memory_analysis — arguments + outputs + temps - aliased, the pattern
    launch.dryrun proves out.  0.0 when the backend exposes nothing."""
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return 0.0
    if mem is None:
        return 0.0
    return float(getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 + getattr(mem, "temp_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))


def xla_peak_bytes(fn, *args) -> float:
    """Lower + compile `fn(*args)` and report its XLA peak bytes/device."""
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    return compiled_peak_bytes(jitted.lower(*args).compile())


def crosscheck_memory(plan, fn, *args) -> dict:
    """The §VI memory-model validation loop: compare a compiled plan's
    *predicted* peak (plan.predicted['memory'], core.perfmodel
    .network_memory) against XLA's measured peak for the train step that
    executes it.  `fn(*args)` must be the step the plan drives (jittable
    or already jitted).  Returns predicted/measured bytes and their ratio
    (nan when the backend reports no memory analysis)."""
    predicted = float(plan.predicted["memory"]["peak_bytes"])
    measured = xla_peak_bytes(fn, *args)
    return {"predicted_bytes": predicted, "measured_bytes": measured,
            "ratio": predicted / measured if measured else float("nan")}


# ---------------------------------------------------------------------------
# what to measure: the shapes and message sizes the model will ask about
# ---------------------------------------------------------------------------

def _local_shards(layer: ConvLayer, dist, mesh_shape):
    """Mirror of perfmodel.layer_cost's shard arithmetic for one dist."""
    n_l = layer.n // max(dist.ways("N", mesh_shape), 1)
    h_l = layer.h // max(dist.ways("H", mesh_shape), 1)
    w_l = layer.w // max(dist.ways("W", mesh_shape), 1)
    c_l = layer.c // max(dist.ways("C", mesh_shape), 1)
    f_l = layer.f // max(dist.ways("F", mesh_shape), 1)
    p_c = dist.ways("C", mesh_shape)
    p_f = dist.ways("F", mesh_shape)
    return n_l, c_l, h_l, w_l, f_l, p_c, p_f


def table_shapes(specs: Sequence[ConvLayer], mesh_shape: Mapping[str, int],
                 allow_w_split: bool = True,
                 allow_channel_filter: bool = True) -> list[tuple]:
    """Every EmpiricalTable key `layer_cost` can query while solving these
    layers over this mesh: for each executable candidate distribution, the
    local forward/BPw conv shape and the BPx data-conv shape (Eq. 2/3)."""
    keys = set()
    for layer in specs:
        for d in executable_candidates(layer, mesh_shape, allow_w_split,
                                       allow_channel_filter):
            n_l, c_l, h_l, w_l, f_l, p_c, p_f = \
                _local_shards(layer, d, mesh_shape)
            f_fwd = layer.f if p_c > 1 else f_l
            keys.add((layer.kind, n_l, c_l, h_l, w_l, f_fwd,
                      layer.k, layer.s))
            if layer.kind != "pool":
                c_bpx = layer.c if p_f > 1 else c_l
                keys.add((layer.kind, n_l, c_bpx, h_l, w_l, f_l,
                          layer.k, layer.s))
    return sorted(keys)


def comm_sizes(specs: Sequence[ConvLayer], mesh_shape: Mapping[str, int],
               wordsize: int = 4,
               allow_w_split: bool = True,
               allow_channel_filter: bool = True
               ) -> tuple[list[int], list[int]]:
    """(p2p bytes, collective bytes) the §V-A/B cost terms will charge for
    these layers: halo SR messages, CF reduce-scatter/all-gather payloads,
    the dL/dw allreduce and the §III-C shuffle blocks."""
    p_total = 1
    for sz in mesh_shape.values():
        p_total *= sz
    p2p, coll = set(), set()
    for layer in specs:
        coll.add(int(layer.weight_words()) * wordsize)       # BPa allreduce
        # §III-C shuffle: priced by all_to_all_time with the *p2p* α/β
        # (pairwise exchange), so its per-processor block must be sampled
        # by the p2p grid, not the collective one
        p2p.add(int(layer.act_words() / max(p_total, 1)) * wordsize)
        for d in executable_candidates(layer, mesh_shape, allow_w_split,
                                       allow_channel_filter):
            n_l, c_l, h_l, w_l, f_l, p_c, p_f = \
                _local_shards(layer, d, mesh_shape)
            o = layer.o
            h_out_l = layer.h_out // max(d.ways("H", mesh_shape), 1)
            w_out_l = layer.w_out // max(d.ways("W", mesh_shape), 1)
            # dL/dy halos run at the *output* extents (layer_cost's
            # halo_dy), so strided layers sample the smaller message too
            if o and d.ways("H", mesh_shape) > 1:
                p2p.add(o * n_l * c_l * w_l * wordsize)      # halo on x
                p2p.add(o * n_l * f_l * w_out_l * wordsize)  # halo on dL/dy
            if o and d.ways("W", mesh_shape) > 1:
                p2p.add(o * n_l * c_l * h_l * wordsize)
                p2p.add(o * n_l * f_l * h_out_l * wordsize)
            if p_c > 1:
                coll.add(n_l * layer.f * h_out_l * w_out_l * wordsize)
            if p_f > 1:
                coll.add(n_l * layer.c * h_l * w_l * wordsize)
    return (sorted(b for b in p2p if b > 0),
            sorted(b for b in coll if b > 0))


def _representative(values: Sequence, cap: int) -> list:
    """A deterministic <=cap subset spread evenly over the sorted range
    (always keeping the extremes) — the benchmark grid stays bounded while
    covering the span the model will interpolate over."""
    values = sorted(set(values))
    if len(values) <= cap:
        return values
    idx = np.linspace(0, len(values) - 1, cap).round().astype(int)
    return [values[i] for i in sorted(set(idx.tolist()))]


def _choose_shapes(wanted: Sequence[tuple], max_shapes: int) -> list[tuple]:
    """The deterministic <=max_shapes subset a calibration run measures:
    spread over the FLOP range so both the launch-bound tail and the
    throughput-bound head get covered.  `coverage` recomputes this, so a
    legitimately capped calibration is judged against what a fresh run
    would measure, not the full (unmeasurable) candidate set."""
    by_flops = sorted(wanted, key=lambda k: (_conv_flops_bytes(k)[0], k))
    return [by_flops[i]
            for i in _representative(range(len(by_flops)), max_shapes)]


# ---------------------------------------------------------------------------
# microbenchmarks (timer-injectable: tests pass a deterministic fake)
# ---------------------------------------------------------------------------

Timer = Callable[..., float]        # timer(fn, *args) -> seconds/call


def _bench_conv_shape(key: tuple, timer: Timer) -> float | None:
    """Time the local dense kernel for one table key on the live backend —
    the per-shard compute the paper times as cuDNN."""
    kind, n, c, h, w, f, k, s = key
    if min(n, c, h, w, f) <= 0:
        return None
    rk = jax.random.PRNGKey(0)
    if kind == "pool":
        x = jax.random.normal(rk, (n, h, w, c), jnp.float32)
        from repro.core.spatial_conv import _pool_windows
        pads = ((0, 0), same_pads(k, s), same_pads(k, s), (0, 0))
        fn = jax.jit(lambda x: _pool_windows(x, (k, k), (s, s), pads, "max"))
        return timer(fn, x)
    x = jax.random.normal(rk, (n, h, w, c), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f),
                           jnp.float32) * 0.1
    fn = jax.jit(lambda x, w: lax.conv_general_dilated(
        x, w, (s, s), (same_pads(k, s), same_pads(k, s)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return timer(fn, x, wt)


def _bench_p2p(mesh, axis: str, nbytes: int, timer: Timer) -> float:
    """One halo-pattern ppermute ring step: every device sends and receives
    `nbytes` — the perf model's SR(n) primitive."""
    n = dict(mesh.shape)[axis]
    elems = max(1, nbytes // 4)
    x = jax.device_put(jnp.zeros((n * elems,), jnp.float32),
                       NamedSharding(mesh, P(axis)))
    perm = [(i, (i + 1) % n) for i in range(n)]
    fn = jax.jit(shard_map(lambda v: lax.ppermute(v, axis, perm),
                           mesh=mesh, in_specs=(P(axis),),
                           out_specs=P(axis)))
    return timer(fn, x)


def _bench_collective(mesh, axis: str, op: str, nbytes: int,
                      timer: Timer) -> float:
    """allreduce / reduce-scatter / all-gather of an `nbytes` buffer over
    one mesh axis — the collective terms of §V-A (CF conv, BPa)."""
    n = dict(mesh.shape)[axis]
    elems = max(n, nbytes // 4) // n * n      # divisible by the group
    if op == "allreduce":
        x = jax.device_put(jnp.ones((elems,), jnp.float32),
                           NamedSharding(mesh, P()))
        body = lambda v: lax.psum(v, axis)                  # noqa: E731
        in_spec, out_spec = P(), P()
    elif op == "reduce_scatter":
        x = jax.device_put(jnp.ones((elems,), jnp.float32),
                           NamedSharding(mesh, P()))
        body = lambda v: lax.psum_scatter(                  # noqa: E731
            v, axis, scatter_dimension=0, tiled=True)
        in_spec, out_spec = P(), P(axis)
    elif op == "all_gather":
        x = jax.device_put(jnp.ones((elems,), jnp.float32),
                           NamedSharding(mesh, P(axis)))
        body = lambda v: lax.all_gather(v, axis, axis=0,    # noqa: E731
                                        tiled=True)
        in_spec, out_spec = P(axis), P()
    else:
        raise ValueError(op)
    # forward-only timing: replication tracking is off because a psum over
    # one axis of a fully-replicated input defeats the legacy checker's
    # inference (nothing is differentiated here, so it is safe).
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(in_spec,),
                           out_specs=out_spec, check_vma=False,
                           legacy_check_rep=False))
    return timer(fn, x)


def _bench_membw(timer: Timer, nbytes: int = 32 << 20) -> float:
    """Achieved streaming bandwidth (read+write) from a saxpy-style pass."""
    x = jnp.zeros((nbytes // 4,), jnp.float32)
    t = timer(jax.jit(lambda v: v + 1.0), x)
    return 2 * nbytes / max(t, 1e-9)


def _bench_overlap(mesh, axis: str, timer: Timer, rounds: int = 3,
                   n: int = 2, c: int = 8, f: int = 8, k: int = 3) -> dict:
    """Interleaved overlapped-vs-serialized A/B of the §IV-A schedule on
    one mesh axis: the same H-split conv step with the interior/boundary
    schedule on vs forced serial, plus a halo-free local conv at the shard
    shape as the compute-only anchor.  The achieved-overlap efficiency is
    the measured gain over the hideable min(comm, compute):

        η = (t_serial − t_overlap) / min(t_serial − t_compute, t_compute)

    clamped to [0, 1]; None when the comm term is too small to resolve
    above timing noise (the sample is kept in meta for inspection but
    excluded from the fit)."""
    from repro.core.spatial_conv import ConvSharding, spatial_conv2d
    p = dict(mesh.shape)[axis]
    h_l = max(4 * k, 16)
    h, w = h_l * p, 64
    sh = ConvSharding(h_axis=axis)
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (n, h, w, c), jnp.float32),
        NamedSharding(mesh, sh.x_spec()))
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f),
                           jnp.float32) * 0.1
    ov_fn = jax.jit(lambda x, w: spatial_conv2d(
        x, w, strides=(1, 1), sharding=sh, mesh=mesh, overlap=True))
    ser_fn = jax.jit(lambda x, w: spatial_conv2d(
        x, w, strides=(1, 1), sharding=sh, mesh=mesh, overlap=False))
    x_loc = jax.random.normal(jax.random.PRNGKey(2), (n, h_l, w, c),
                              jnp.float32)
    loc_fn = jax.jit(lambda x, w: lax.conv_general_dilated(
        x, w, (1, 1), (same_pads(k, 1), same_pads(k, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    t_ov, t_ser = [], []
    for _ in range(rounds):     # alternate arms so clock drift hits both
        t_ov.append(timer(ov_fn, x, wt))
        t_ser.append(timer(ser_fn, x, wt))
    t_ov, t_ser = min(t_ov), min(t_ser)
    t_loc = timer(loc_fn, x_loc, wt)
    comm = max(t_ser - t_loc, 0.0)
    hideable = min(comm, t_loc)
    eta = None
    if hideable > 0.05 * t_ser:
        eta = min(max((t_ser - t_ov) / hideable, 0.0), 1.0)
    return {"axis": axis, "p": p, "t_overlap": t_ov, "t_serial": t_ser,
            "t_compute": t_loc, "eta": eta}


def fit_eta(mesh, *, timer: Timer | None = None, reps: int = 5,
            base: Machine = HOST_BASE) -> tuple[float, list]:
    """Measure the achieved-overlap efficiency η (Machine.overlap_eta)
    over every size > 1 mesh axis and take the median across axes.

    Returns (base.overlap_eta, []) when `mesh` carries no live multi-device
    axis (a plain {axis: size} mapping, or every axis of size 1): an
    analytic calibration keeps the optimistic default rather than inventing
    a measurement it cannot make."""
    if timer is None:
        timer = lambda fn, *a: time_fn(fn, *a, reps=reps)   # noqa: E731
    mesh_shape = _mesh_shape_of(mesh)
    real_mesh = mesh if hasattr(mesh, "devices") else None
    axes = sorted(ax for ax, sz in mesh_shape.items() if sz > 1) \
        if real_mesh is not None else []
    samples = [_bench_overlap(real_mesh, ax, timer) for ax in axes]
    etas = [s["eta"] for s in samples if s["eta"] is not None]
    eta = float(np.median(etas)) if etas else base.overlap_eta
    return eta, samples


# ---------------------------------------------------------------------------
# composition microbenchmarks: what a §III-C shuffle, a product-axis halo
# and a CF collective *inside* a halo'd spatial block actually cost — the
# terms where the composed workloads' 4–13× model/measured gap lives
# ---------------------------------------------------------------------------

def shuffle_sizes(specs: Sequence[ConvLayer],
                  mesh_shape: Mapping[str, int],
                  wordsize: int = 4) -> list[tuple[int, int]]:
    """The (p_total, local_bytes) shuffle keys a plan transition over these
    layers can price — shuffle_block_bytes is the shared definition, so the
    measured `shuffle:` entries land on exactly the keys shuffle_time asks
    for."""
    p_total = 1
    for sz in mesh_shape.values():
        p_total *= sz
    out = set()
    for layer in specs:
        nb = shuffle_block_bytes(layer, p_total, wordsize)
        if nb > 0:
            out.add((p_total, nb))
    return sorted(out)


def _bench_shuffle(mesh, axes: Sequence[str], nbytes: int,
                   timer: Timer) -> float:
    """One direction of a §III-C shuffle: reshard a (p, elems) array from
    row-sharded to column-sharded over the product of `axes` — the
    all-to-all transpose every dist change pays, at `nbytes` local."""
    shape = dict(mesh.shape)
    p = 1
    for ax in axes:
        p *= shape[ax]
    elems = max(p, nbytes // 4) // p * p
    src = NamedSharding(mesh, P(tuple(axes), None))
    dst = NamedSharding(mesh, P(None, tuple(axes)))
    x = jax.device_put(jnp.zeros((p, elems), jnp.float32), src)
    fn = jax.jit(lambda v: lax.with_sharding_constraint(v, dst))
    return timer(fn, x)


def _bench_product_halo(mesh, axes: tuple[str, str], timer: Timer,
                        n: int = 2, c: int = 8, f: int = 8,
                        k: int = 3) -> dict:
    """Serialized H-split conv with H over a *product* of two mesh axes
    (boundary-crossing hops), plus the local conv at the shard shape as the
    compute-only anchor — (t_fused − t_compute) isolates the measured halo
    exchange the model prices with sr_time(…, hops=2)."""
    from repro.core.spatial_conv import ConvSharding, spatial_conv2d
    shape = dict(mesh.shape)
    p = shape[axes[0]] * shape[axes[1]]
    h_l = max(4 * k, 16)
    h, w = h_l * p, 32
    sh = ConvSharding(h_axis=tuple(axes))
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (n, h, w, c), jnp.float32),
        NamedSharding(mesh, sh.x_spec()))
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f),
                           jnp.float32) * 0.1
    ser_fn = jax.jit(lambda x, w: spatial_conv2d(
        x, w, strides=(1, 1), sharding=sh, mesh=mesh, overlap=False))
    x_loc = jax.random.normal(jax.random.PRNGKey(2), (n, h_l, w, c),
                              jnp.float32)
    loc_fn = jax.jit(lambda x, w: lax.conv_general_dilated(
        x, w, (1, 1), (same_pads(k, 1), same_pads(k, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return {"axes": list(axes), "p": p,
            "t_fused": timer(ser_fn, x, wt),
            "t_compute": timer(loc_fn, x_loc, wt),
            "geom": {"o": k // 2, "n": n, "c": c, "h_l": h_l, "w_l": w,
                     "hops": 2}}


def _bench_composed_cf(mesh, cf_axis: str, sp_axis: str, timer: Timer,
                       n: int = 2, k: int = 3) -> dict:
    """Serialized fused CF×spatial conv (the §III-D reduce-scatter running
    *inside* an H-split shard_map) plus its local-conv anchor — what the CF
    collective costs when composed with a halo'd spatial block, vs the
    standalone collective fit."""
    from repro.core.channel_conv import CFSharding, cf_conv2d
    shape = dict(mesh.shape)
    p_cf, p_sp = shape[cf_axis], shape[sp_axis]
    c = f = 8 * p_cf
    h_l = max(4 * k, 16)
    h, w = h_l * p_sp, 32
    sh = CFSharding(cf_axis=cf_axis, h_axis=sp_axis, mode="channel")
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (n, h, w, c), jnp.float32),
        NamedSharding(mesh, sh.x_spec()))
    wt = jax.random.normal(jax.random.PRNGKey(1), (k, k, c, f),
                           jnp.float32) * 0.1
    fused_fn = jax.jit(lambda x, w: cf_conv2d(
        x, w, strides=(1, 1), sharding=sh, mesh=mesh, overlap=False))
    # channel mode computes (c_l -> full F) locally, then RS(y) completes
    # the channel sum — the anchor is that local conv at the shard shape
    x_loc = jax.random.normal(jax.random.PRNGKey(2), (n, h_l, w, c // p_cf),
                              jnp.float32)
    wt_loc = wt[:, :, : c // p_cf, :]
    loc_fn = jax.jit(lambda x, w: lax.conv_general_dilated(
        x, w, (1, 1), (same_pads(k, 1), same_pads(k, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return {"cf_axis": cf_axis, "sp_axis": sp_axis,
            "p_cf": p_cf, "p_sp": p_sp,
            "t_fused": timer(fused_fn, x, wt),
            "t_compute": timer(loc_fn, x_loc, wt_loc),
            "geom": {"o": k // 2, "n": n, "c_l": c // p_cf, "f": f,
                     "h_l": h_l, "w_l": w}}


def _clamp(x: float, lo: float, hi: float) -> float:
    return min(max(x, lo), hi)


def _fit_composed_factors(m: Machine, cf_samples: Sequence[Mapping],
                          halo_samples: Sequence[Mapping]
                          ) -> tuple[float, float]:
    """(composed_cf_factor, composed_halo_factor) from the fused
    microbenchmarks, decomposed against the *fitted* machine `m` so the
    factors isolate what composition adds on top of the standalone α-β
    fits.  Per-sample ratios are clamped to [0.25, 8] (a factor outside
    that is a measurement failure, not a model truth) and the median is
    taken; 1.0 when nothing measured."""
    ws = 4                       # the benches allocate float32
    halo_ratios = []
    for s in halo_samples:
        g = s["geom"]
        pred = _halo_time(m, g["o"], g["n"], g["c"], g["h_l"], g["w_l"],
                          g["hops"], 0)
        meas = s["t_fused"] - s["t_compute"]
        if pred > 0 and meas > 0:
            halo_ratios.append(_clamp(meas / pred, 0.25, 8.0))
    cf_ratios = []
    for s in cf_samples:
        g = s["geom"]
        pred_halo = _halo_time(m, g["o"], g["n"], g["c_l"], g["h_l"],
                               g["w_l"], 1, 0)
        pred_cf = reduce_scatter_time(
            m, s["p_cf"], g["n"] * g["f"] * g["h_l"] * g["w_l"] * ws)
        meas = s["t_fused"] - s["t_compute"] - pred_halo
        if pred_cf > 0 and meas > 0:
            cf_ratios.append(_clamp(meas / pred_cf, 0.25, 8.0))
    cf = float(np.median(cf_ratios)) if cf_ratios else 1.0
    halo = float(np.median(halo_ratios)) if halo_ratios else 1.0
    return cf, halo


def _measure_composition(specs: Sequence[ConvLayer], real_mesh,
                         mesh_shape: Mapping[str, int],
                         comm_axes: Sequence[str], machine: Machine,
                         timer: Timer, max_sizes: int,
                         wordsize: int) -> dict:
    """Run the composed-cost microbenchmarks against an already-fitted
    `machine` and return the table entries + fitted correction factors —
    shared by calibrate() and load_or_run's backfill of pre-composition
    files.  No live comm axes -> analytic defaults (factors 1.0, empty
    entries), mirroring fit_eta's discipline."""
    entries: dict[tuple, float] = {}
    shuffle_samples: list[list] = []       # [p, nbytes, seconds]
    if comm_axes:
        for p_tot, nb in _representative(
                shuffle_sizes(specs, mesh_shape, wordsize), max_sizes):
            t = _bench_shuffle(real_mesh, comm_axes, nb, timer)
            entries[(SHUFFLE_KIND, p_tot, nb)] = t
            shuffle_samples.append([p_tot, nb, t])
    ratios = []
    for p, nb, t in shuffle_samples:
        pred = all_to_all_time(machine, p, nb)
        if pred > 0 and t > 0:
            ratios.append(_clamp(t / pred, 0.25, 8.0))
    shuffle_factor = float(np.median(ratios)) if ratios else 1.0

    cf_samples, halo_samples = [], []
    if len(comm_axes) >= 2:
        a0, a1 = comm_axes[0], comm_axes[1]
        cf_samples = [_bench_composed_cf(real_mesh, a0, a1, timer),
                      _bench_composed_cf(real_mesh, a1, a0, timer)]
        halo_samples = [_bench_product_halo(real_mesh, (a0, a1), timer)]
        for s in cf_samples:
            entries[("composed:cf", s["p_cf"], s["p_sp"])] = s["t_fused"]
        for s in halo_samples:
            entries[("composed:halo", s["p"], s["geom"]["hops"])] = \
                s["t_fused"]
    cf_factor, halo_factor = _fit_composed_factors(machine, cf_samples,
                                                   halo_samples)
    return {"entries": entries,
            "shuffle_factor": shuffle_factor,
            "cf_factor": cf_factor,
            "halo_factor": halo_factor,
            "shuffle_samples": shuffle_samples,
            "cf_samples": cf_samples,
            "halo_samples": halo_samples}


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

def _fit_alpha_beta(rows: Sequence[tuple[float, float, float]],
                    default: tuple[float, float]) -> tuple[float, float]:
    """Least squares for t = a_coef*α + b_coef*β over (a_coef, b_coef, t)
    samples; falls back to `default` when the system is degenerate."""
    if len(rows) < 2:
        return default
    A = np.array([[r[0], r[1]] for r in rows], dtype=np.float64)
    y = np.array([r[2] for r in rows], dtype=np.float64)
    if np.linalg.matrix_rank(A) < 2:
        return default
    (alpha, beta), *_ = np.linalg.lstsq(A, y, rcond=None)
    return max(float(alpha), 1e-8), max(float(beta), 1e-13)


def _fit_compute(samples: Sequence[tuple[float, float]],
                 base: Machine) -> tuple[float, float, float]:
    """(peak_flops, efficiency, halfwork) from (flops, seconds) conv samples.

    The analytic model prices a compute-bound conv at
    t = (fl + halfwork) / (eff * peak) + launch, so a linear fit of t vs fl
    yields eff*peak from the slope and halfwork from the intercept; peak is
    anchored at the best achieved rate so eff lands in (0, 1]."""
    samples = [(fl, t) for fl, t in samples if fl > 0 and t > 0]
    if not samples:
        return base.peak_flops, base.compute_efficiency, base.eff_halfwork
    peak = max(fl / t for fl, t in samples)
    if len({fl for fl, _ in samples}) < 2:
        return peak, 1.0, 0.0
    A = np.array([[fl, 1.0] for fl, _ in samples], dtype=np.float64)
    y = np.array([t for _, t in samples], dtype=np.float64)
    (slope, intercept), *_ = np.linalg.lstsq(A, y, rcond=None)
    if slope <= 0:
        return peak, 1.0, 0.0
    eff = min(1.0, max(0.05, 1.0 / (slope * peak)))
    halfwork = max(0.0, (float(intercept) - LAUNCH_OVERHEAD) / float(slope))
    return peak, eff, halfwork


def _conv_flops_bytes(key: tuple, wordsize: int = 4) -> tuple[float, float]:
    kind, n, c, h, w, f, k, s = key
    h_out, w_out = -(-h // s), -(-w // s)
    if kind == "pool":
        return (float(n * f * h_out * w_out * k * k),
                float((n * c * h * w + n * f * h_out * w_out) * wordsize))
    return (2.0 * n * c * h_out * w_out * k * k * f,
            float((n * c * h * w + n * f * h_out * w_out + k * k * c * f)
                  * wordsize))


# ---------------------------------------------------------------------------
# the calibration object (JSON round-trip)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Calibration:
    """A fitted Machine + measured EmpiricalTable + provenance metadata —
    everything the solver needs to run on measured costs."""
    machine: Machine
    table: EmpiricalTable
    meta: dict

    def to_json(self) -> dict:
        return {"schema": SCHEMA,
                "machine": dataclasses.asdict(self.machine),
                "table": self.table.to_json(),
                "meta": self.meta}

    @classmethod
    def from_json(cls, obj: Mapping) -> "Calibration":
        if obj.get("schema") != SCHEMA:
            raise ValueError(f"not a calibration file "
                             f"(schema={obj.get('schema')!r}, "
                             f"expected {SCHEMA!r})")
        return cls(machine=Machine(**obj["machine"]),
                   table=EmpiricalTable.from_json(obj["table"]),
                   meta=dict(obj.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "Calibration":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def summary(self) -> str:
        m = self.machine
        return (f"{m.name}: {len(self.table)} table entries, "
                f"peak {m.peak_flops/1e9:.1f} GFLOP/s "
                f"(eff {m.compute_efficiency:.2f}, "
                f"halfwork {m.eff_halfwork:.2e}), "
                f"capacity {m.mem_capacity/2**30:.1f} GiB/device, "
                f"mem {m.mem_bw/1e9:.1f} GB/s, "
                f"overlap eta {m.overlap_eta:.2f}, "
                f"p2p a={m.alpha*1e6:.1f}us b=1/{1/m.beta/1e9:.2f}GB/s, "
                f"coll a={m.alpha_coll*1e6:.1f}us "
                f"b=1/{1/m.beta_coll/1e9:.2f}GB/s")


# ---------------------------------------------------------------------------
# the calibration run
# ---------------------------------------------------------------------------

def _mesh_shape_of(mesh) -> dict[str, int]:
    if mesh is None:
        return {}
    if isinstance(mesh, Mapping):
        return dict(mesh)
    return dict(mesh.shape)


def calibrate(specs: Sequence[ConvLayer], mesh, *,
              base: Machine = HOST_BASE,
              reps: int = 5,
              max_shapes: int = 64,
              max_sizes: int = 5,
              timer: Timer | None = None,
              allow_w_split: bool = True,
              allow_channel_filter: bool = True) -> Calibration:
    """Microbenchmark + fit for `specs` over `mesh` on the live backend.

    `mesh` may be a jax Mesh (communication axes of size > 1 are measured)
    or a plain {axis: size} mapping (shapes only — comm constants keep the
    `base` values).  `timer(fn, *args) -> seconds` defaults to the shared
    trimmed-mean loop (repro.utils.time_fn); tests inject a deterministic
    fake so calibration logic is checkable without wall clocks.
    """
    if timer is None:
        timer = lambda fn, *a: time_fn(fn, *a, reps=reps)   # noqa: E731
    mesh_shape = _mesh_shape_of(mesh)
    real_mesh = mesh if hasattr(mesh, "devices") else None

    # -- 1. local conv table over the candidate shard shapes ----------------
    wanted = table_shapes(specs, mesh_shape, allow_w_split,
                          allow_channel_filter)
    chosen = _choose_shapes(wanted, max_shapes)
    entries: dict[tuple, float] = {}
    for key in chosen:
        t = _bench_conv_shape(key, timer)
        if t is not None:
            entries[key] = t
    dropped = len(wanted) - len(chosen)
    if dropped:
        print(f"calibrate: capped conv grid at {len(chosen)} of "
              f"{len(wanted)} shapes (analytic fallback covers the rest)")

    # -- 2. communication primitives at the emitted message sizes -----------
    p2p_all, coll_all = comm_sizes(specs, mesh_shape,
                                   wordsize=base.wordsize,
                                   allow_w_split=allow_w_split,
                                   allow_channel_filter=allow_channel_filter)
    p2p_sizes = _representative(p2p_all, max_sizes)
    coll_sizes = _representative(coll_all, max_sizes)
    comm_axes = sorted(ax for ax, sz in mesh_shape.items() if sz > 1) \
        if real_mesh is not None else []

    p2p_samples: list[list] = []        # [axis, nbytes, seconds]
    coll_samples: list[list] = []       # [op, axis, p, nbytes, seconds]
    for ax in comm_axes:
        p = mesh_shape[ax]
        for nbytes in p2p_sizes:
            p2p_samples.append([ax, nbytes,
                                _bench_p2p(real_mesh, ax, nbytes, timer)])
        for op in ("allreduce", "reduce_scatter", "all_gather"):
            for nbytes in coll_sizes:
                coll_samples.append(
                    [op, ax, p, nbytes,
                     _bench_collective(real_mesh, ax, op, nbytes, timer)])

    # -- 3. fit the Machine constants ---------------------------------------
    alpha, beta = _fit_alpha_beta(
        [(1.0, float(nb), t) for _, nb, t in p2p_samples],
        (base.alpha, base.beta))
    # fit the collective fabric from the reduce-scatter / all-gather
    # samples only, whose model coefficients are unambiguous
    # ((p-1)·α + (p-1)/p·n·β).  The allreduce samples are measured for
    # validation (meta) but NOT fitted: perfmodel prices an allreduce as
    # the *min* over candidate algorithms, so attributing the samples to
    # any single algorithm's coefficients would fit constants that
    # under-predict the very samples they were fit to.
    coll_rows = [(float(p - 1), (p - 1) / p * nb, t)
                 for op, _, p, nb, t in coll_samples
                 if op != "allreduce"]
    alpha_coll, beta_coll = _fit_alpha_beta(
        coll_rows, (base.alpha_coll, base.beta_coll))

    conv_fit = [( _conv_flops_bytes(k)[0], t) for k, t in entries.items()
                if k[0] != "pool"]
    peak, eff, halfwork = _fit_compute(conv_fit, base)
    mem_bw = _bench_membw(timer)
    # achieved-overlap efficiency η: interleaved overlapped-vs-serialized
    # A/B per comm axis (see _bench_overlap) — what scales the solver's
    # §IV-A overlap credit down to what this machine actually hides.
    overlap_eta, eta_samples = fit_eta(mesh, timer=timer, base=base)
    if eta_samples:
        # let the runtime's chunked-CF default resolve against the
        # measurement (channel_conv.chunks_decision)
        channel_conv.set_measured_eta(overlap_eta)

    machine = Machine(
        name=f"calibrated-{jax.default_backend()}",
        peak_flops=peak, mem_bw=mem_bw,
        alpha=alpha, beta=beta,
        alpha_coll=alpha_coll, beta_coll=beta_coll,
        wordsize=base.wordsize,
        compute_efficiency=eff, eff_halfwork=halfwork,
        mem_capacity=detect_mem_capacity(),
        overlap_eta=overlap_eta)

    # -- 4. composed costs: §III-C shuffles at the real transition sizes,
    # fused CF×spatial, product-axis halo — measured against the fitted
    # constants above so the correction factors isolate composition -------
    comp = _measure_composition(specs, real_mesh, mesh_shape, comm_axes,
                                machine, timer, max_sizes, base.wordsize)
    entries.update(comp["entries"])
    machine = dataclasses.replace(
        machine,
        composed_cf_factor=comp["cf_factor"],
        composed_halo_factor=comp["halo_factor"],
        shuffle_factor=comp["shuffle_factor"])

    meta = {
        "backend": jax.default_backend(),
        "ndevices": jax.device_count(),
        "mesh": dict(mesh_shape),
        "reps": reps,
        "max_shapes": max_shapes,
        "allow_w_split": allow_w_split,
        "allow_channel_filter": allow_channel_filter,
        "shapes": {"requested": len(wanted), "measured": len(entries),
                   "dropped": dropped},
        "p2p_samples": p2p_samples,
        "collective_samples": coll_samples,
        "eta_fit": {"eta": overlap_eta, "samples": eta_samples},
        "shuffle_fit": {"factor": comp["shuffle_factor"],
                        "samples": comp["shuffle_samples"]},
        "composed_fit": {"cf_factor": comp["cf_factor"],
                         "halo_factor": comp["halo_factor"],
                         "cf_samples": comp["cf_samples"],
                         "halo_samples": comp["halo_samples"]},
        "mem_capacity_source": mem_capacity_source(),
        "layers": [l.name for l in specs],
    }
    return Calibration(machine=machine, table=EmpiricalTable(entries),
                       meta=meta)


def _chosen_shapes_for(cal: Calibration, specs: Sequence[ConvLayer],
                       mesh_shape: Mapping[str, int]) -> list[tuple]:
    """The conv-shape grid a fresh calibration of `specs` over `mesh_shape`
    would measure under `cal`'s own stored settings (shape cap, candidate
    flags) — the single definition both `coverage` and `grow` judge
    against, so the growth policy and the coverage warning cannot drift."""
    m = cal.meta
    wanted = table_shapes(specs, mesh_shape,
                          allow_w_split=m.get("allow_w_split", True),
                          allow_channel_filter=m.get("allow_channel_filter",
                                                     True))
    return _choose_shapes(wanted, int(m.get("max_shapes", 64)))


def coverage(cal: Calibration, specs: Sequence[ConvLayer],
             mesh_shape: Mapping[str, int]) -> float:
    """Fraction of the table keys a fresh calibration of `specs` over
    `mesh_shape` — run with `cal`'s own settings — would measure that
    `cal`'s table actually holds.  Judging against what a run *would
    measure* (not the full candidate set) means a legitimately capped
    self-calibration scores 1.0, while a table measured for a different
    network or mesh scores near 0."""
    chosen = _chosen_shapes_for(cal, specs, mesh_shape)
    if not chosen:
        return 1.0
    return sum(k in cal.table.entries for k in chosen) / len(chosen)


def grow(cal: Calibration, specs: Sequence[ConvLayer], mesh, *,
         reps: int = 5, timer: Timer | None = None) -> int:
    """Measure the conv shapes a calibration of `specs`/`mesh` would pick
    that `cal`'s table is missing, and merge them in — the cross-run table
    growth the CI bench lane relies on (the cached BENCH_calibration.json
    accumulates shard shapes across pushes instead of being re-measured).
    Machine constants are kept: they are shape-independent fits and
    re-fitting them from a partial sample would only add noise.  Returns
    the number of entries added."""
    if timer is None:
        timer = lambda fn, *a: time_fn(fn, *a, reps=reps)   # noqa: E731
    mesh_shape = _mesh_shape_of(mesh)
    chosen = _chosen_shapes_for(cal, specs, mesh_shape)
    missing = [k for k in chosen if k not in cal.table.entries]
    added = 0
    for key in missing:
        t = _bench_conv_shape(key, timer)
        if t is not None:
            cal.table.entries[key] = t
            added += 1
    if added:
        grown = cal.meta.setdefault("grown", [])
        grown.append({"layers": [l.name for l in specs],
                      "mesh": dict(mesh_shape), "added": added})
    return added


def load_or_run(path: str, specs: Sequence[ConvLayer], mesh, *,
                grow_table: bool = False, **kwargs) -> Calibration:
    """Load a calibration from `path` when it exists, else run one over
    `specs`/`mesh` and save it there — the one-liner train.py and the
    benchmarks use to make `--calibrate` idempotent across runs.

    A loaded file is checked against the *requested* specs/mesh: a table
    measured for a different network or mesh mostly misses and silently
    degrades to the analytic model, so low coverage gets a loud warning
    (not an error — a TPU-measured table driving a dry run is legitimate).
    With `grow_table=True` the missing shard shapes are measured on the
    live backend instead and merged back into `path`, so a cached table
    (CI's actions/cache) accumulates coverage across runs.
    """
    if path and os.path.exists(path):
        cal = Calibration.load(path)
        print(f"calibration loaded from {path}: {cal.summary()}")
        mesh_shape = _mesh_shape_of(mesh)
        if cal.meta.get("mesh") not in (None, dict(mesh_shape)):
            print(f"calibrate: WARNING: {path} was measured on mesh "
                  f"{cal.meta['mesh']}, not {dict(mesh_shape)}")
        if "eta_fit" not in cal.meta:
            # a pre-η calibration file: backfill the achieved-overlap
            # measurement now (the Machine JSON simply lacked the field and
            # deserialized at the optimistic η=1 default) and persist it.
            eta, samples = fit_eta(mesh, timer=kwargs.get("timer"),
                                   reps=kwargs.get("reps", 5))
            cal.machine = dataclasses.replace(cal.machine, overlap_eta=eta)
            cal.meta["eta_fit"] = {"eta": eta, "samples": samples}
            if path:
                cal.save(path)
            print(f"calibrate: backfilled overlap eta={eta:.2f} into {path}")
        if "mem_capacity_source" not in cal.meta:
            cal.meta["mem_capacity_source"] = mem_capacity_source()
            if path:
                cal.save(path)
        if "shuffle_fit" not in cal.meta or \
                "composed_fit" not in cal.meta:
            # a pre-composition calibration file: measure the §III-C
            # shuffle / fused-composition benches now against the stored
            # machine constants (the Machine JSON simply lacked the factor
            # fields and deserialized at the analytic 1.0 defaults), record
            # the capacity-detection source, and persist.
            timer = kwargs.get("timer")
            if timer is None:
                reps = kwargs.get("reps", 5)
                timer = lambda fn, *a: time_fn(fn, *a,      # noqa: E731
                                               reps=reps)
            mesh_shape = _mesh_shape_of(mesh)
            real_mesh = mesh if hasattr(mesh, "devices") else None
            comm_axes = sorted(ax for ax, sz in mesh_shape.items()
                               if sz > 1) if real_mesh is not None else []
            comp = _measure_composition(
                specs, real_mesh, mesh_shape, comm_axes, cal.machine,
                timer, kwargs.get("max_sizes", 5),
                cal.machine.wordsize)
            cal.table.entries.update(comp["entries"])
            cal.machine = dataclasses.replace(
                cal.machine,
                composed_cf_factor=comp["cf_factor"],
                composed_halo_factor=comp["halo_factor"],
                shuffle_factor=comp["shuffle_factor"])
            cal.meta.setdefault(
                "shuffle_fit", {"factor": comp["shuffle_factor"],
                                "samples": comp["shuffle_samples"]})
            cal.meta.setdefault(
                "composed_fit", {"cf_factor": comp["cf_factor"],
                                 "halo_factor": comp["halo_factor"],
                                 "cf_samples": comp["cf_samples"],
                                 "halo_samples": comp["halo_samples"]})
            if path:
                cal.save(path)
            print(f"calibrate: backfilled composed-cost fit into {path} "
                  f"(shuffle x{comp['shuffle_factor']:.2f}, "
                  f"cf x{comp['cf_factor']:.2f}, "
                  f"halo x{comp['halo_factor']:.2f})")
        ef = cal.meta.get("eta_fit") or {}
        if ef.get("samples"):
            # loaded file carries a real measurement — install it for the
            # runtime's chunked-CF default, same as a fresh calibrate()
            channel_conv.set_measured_eta(ef["eta"])
        if grow_table:
            added = grow(cal, specs, mesh,
                         reps=kwargs.get("reps", 5),
                         timer=kwargs.get("timer"))
            if added:
                cal.save(path)
                print(f"calibrate: grew {path} by {added} table entries "
                      f"({len(cal.table)} total)")
        cov = coverage(cal, specs, mesh_shape)
        if cov < 0.5:
            print(f"calibrate: WARNING: {path} covers only {cov:.0%} of "
                  f"this network's shard shapes — the rest falls back to "
                  f"the analytic model; delete the file (or pass another "
                  f"path) to re-measure for this network")
        return cal
    cal = calibrate(specs, mesh, **kwargs)
    if path:
        cal.save(path)
        print(f"calibration written to {path}: {cal.summary()}")
    return cal


def refit_from_attribution(cal: Calibration, report: Mapping, *,
                           path: str | None = None,
                           damp: float = 1.0) -> dict:
    """Close the attribution loop: fold a measured per-term drift report
    (NetworkPlan.attribution_report / BENCH_attribution.json) back into the
    calibration's composition factors, so model/measured drift *drives
    recalibration* instead of only printing a warning.

    The comm-side term drifts map onto the factors that price them:
    `shuffle` -> shuffle_factor; `fp_comm`/`bp_comm` (halo + CF
    collectives, which the composed workloads dominate with composed
    terms) -> both composed factors, weighted by predicted seconds.
    Compute-side terms (fp/bp_compute, bpa) are left to the conv table and
    the collective fit — nudging factors by compute drift would smear
    kernel noise over comm terms.

    Each factor takes a multiplicative step drift**damp clamped to
    [0.25, 4] per refit and [0.1, 10] absolute; the applied steps append to
    meta["attribution_refits"].  Saves to `path` when given.  Returns the
    {factor: new value} dict of what changed."""
    terms = report.get("terms") or {}

    def drift_of(*names):
        num = den = 0.0
        for t in names:
            row = terms.get(t)
            if row and row.get("predicted_s", 0) > 0 and \
                    row.get("drift", 0) > 0:
                num += row["predicted_s"] * row["drift"]
                den += row["predicted_s"]
        return (num / den) if den > 0 else None

    def step(cur, drift):
        mult = _clamp(drift ** damp, 0.25, 4.0)
        return _clamp(cur * mult, 0.1, 10.0)

    changed: dict[str, float] = {}
    sh_drift = drift_of("shuffle")
    if sh_drift is not None:
        changed["shuffle_factor"] = step(cal.machine.shuffle_factor,
                                         sh_drift)
    comm_drift = drift_of("fp_comm", "bp_comm")
    if comm_drift is not None:
        changed["composed_cf_factor"] = step(
            cal.machine.composed_cf_factor, comm_drift)
        changed["composed_halo_factor"] = step(
            cal.machine.composed_halo_factor, comm_drift)
    if changed:
        cal.machine = dataclasses.replace(cal.machine, **changed)
        cal.meta.setdefault("attribution_refits", []).append(
            {"worst_term": report.get("worst_term"),
             "drifts": {"shuffle": sh_drift, "comm": comm_drift},
             "applied": dict(changed)})
        if path:
            cal.save(path)
    return changed


# ---------------------------------------------------------------------------
# CLI:  PYTHONPATH=src python -m repro.core.calibrate --arch mesh1k --smoke
# (fake multi-device with XLA_FLAGS=--xla_force_host_platform_device_count=N)
# ---------------------------------------------------------------------------

def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="Calibrate the §V perf model on the live backend and "
                    "write BENCH_calibration.json")
    ap.add_argument("--arch", default="mesh1k",
                    help="CNN arch whose layer shapes seed the table "
                         "(mesh1k | mesh2k | resnet50)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--max-shapes", type=int, default=64)
    ap.add_argument("--out", default=DEFAULT_PATH)
    args = ap.parse_args(argv)

    from repro.configs import registry
    from repro.launch.mesh import make_mesh
    arch = registry.canon(args.arch)
    if arch not in registry.CNN_ARCHS:
        ap.error(f"--arch {args.arch}: calibration covers the CNN archs "
                 f"{registry.CNN_ARCHS}")
    cfg = registry.get(arch, smoke=args.smoke)
    if arch == "resnet50":
        from repro.models.cnn import resnet
        specs = resnet.layer_specs(args.batch, cfg)
    else:
        from repro.models.cnn import meshnet
        specs = meshnet.layer_specs(cfg, args.batch)
    mesh = make_mesh(data=args.data, model=args.model)
    # load_or_run keeps the CLI idempotent: an existing --out is loaded
    # (with the coverage check), never silently re-measured over
    cal = load_or_run(args.out, specs, mesh, reps=args.reps,
                      max_shapes=args.max_shapes)
    print(cal.summary())


if __name__ == "__main__":
    main()
