"""Strategy-to-execution plan compiler (paper §V-C output -> runtime).

`strategy.solve_dag` / `solve_line` answer the paper's optimization problem
with a `{layer name: Dist}` map — a *mathematical* object.  This module
lowers that map into a `NetworkPlan` the models execute:

  * each layer's `Dist` becomes the runtime sharding descriptor that drives
    execution: a `ConvSharding` for sample/spatial distributions (the
    halo-exchange conv/pool/BN implementations, core.spatial_conv) or a
    `CFSharding` for channel/filter distributions (§III-D — the
    row/column-parallel conv in core.channel_conv, the paper's "hidden
    dimension" parallelism for late, channel-heavy layers whose spatial
    extents are too small to split);
  * a distribution change between consecutive layers becomes an explicit
    reshard point — the paper's Shuffle(D_i, D_j) (§III-C) — lowered to
    ``lax.with_sharding_constraint`` so GSPMD materializes the all-to-all
    exactly where the optimizer paid for it;
  * every layer is validated against its geometry (the `ConvSharding.fit`
    edge cases, §III-A): a distribution the runtime would demote (spatial
    shard smaller than the kernel, non-divisible extents, channel counts
    that do not divide the CF mesh axis) is demoted at *compile* time and
    recorded, so the perf-model prediction stays honest;
  * mesh axes of size 1 are dropped (they provide no parallelism), which
    makes a plan solved on a 1x1 mesh execute the exact single-device code
    path — the oracle-equivalence contract the tests pin down;
  * the compiled plan carries a predicted cost report (core.perfmodel) so
    measured step time can be cross-checked against the model
    (benchmarks/strategy_exec.py).

A `NetworkPlan` built with `NetworkPlan.uniform(conv_sharding)` reproduces
the legacy one-`ConvSharding`-for-every-layer behavior bit for bit, which is
how existing callers keep working.

Mixed plans compose freely: a solved network can open with hybrid
sample+spatial layers, switch late layers to channel/filter parallelism
when the solver prices the halo above the reduce-scatter, and close with a
sample-parallel head — each transition is one recorded reshard point.
`examples/quickstart.py` demos such a mixed spatial+CF plan end to end.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Mapping, Sequence

import jax
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.channel_conv import CFSharding
from repro.core.distribution import Dist
from repro.core.perfmodel import (ConvLayer, EmpiricalTable, Machine,
                                  cf_mode_for, network_cost)
from repro.core.spatial_conv import ConvSharding
from repro.core.strategy import candidate_dists, solve_dag, solve_line


class PlanError(ValueError):
    """A distribution map cannot be lowered to an executable plan.

    Messages name the offending layer (when known) and dist, and suggest
    the nearest executable demotion so callers can fix their map."""


# ---------------------------------------------------------------------------
# Dist -> ConvSharding lowering
# ---------------------------------------------------------------------------

def normalize_dist(d: Dist, mesh_shape: Mapping[str, int]) -> Dist:
    """Drop mesh axes of size 1 — they contribute no parallelism, and
    dropping them lets size-1 meshes take the dense single-device path."""
    dims = {k: tuple(a for a in axes if mesh_shape.get(a, 1) > 1)
            for k, axes in d.dims.items()}
    dims = {k: v for k, v in dims.items() if v}
    return Dist(d.name, dims)


def _demoted(d: Dist, keep: set[str]) -> Dist:
    """The nearest executable demotion: `d` restricted to dims in `keep`."""
    return Dist(d.name + "-demoted",
                {k: v for k, v in d.dims.items() if k in keep})


def _dist_str(d: Dist) -> str:
    dims = " ".join(f"{k}:{','.join(v)}" for k, v in d.dims.items())
    return f"{d.name!r} ({dims or 'replicated'})"


def _spatial_axis(axes: tuple[str, ...]):
    """A spatial dim's runtime axis spec: None / bare axis / product tuple
    (core.halo's linearized product-axis convention for multi-axis splits,
    the 16x16-mesh case)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def dist_to_sharding(d: Dist, mesh_shape: Mapping[str, int],
                     layer: str | None = None):
    """Lower a Dist to its runtime sharding descriptor, or raise PlanError.

    Sample (N) and spatial distributions — H and/or W, each over one mesh
    axis or a *product* of axes (core.halo) — lower to `ConvSharding`;
    channel/filter distributions (§III-D, C and F paired on one mesh axis),
    optionally composed with spatial sharding on different axes, lower to
    `CFSharding` (core.channel_conv).  `layer` (when known) names the
    offending layer in diagnostics.
    """
    d = normalize_dist(d, mesh_shape)
    who = f"layer {layer!r}: " if layer else ""
    c_ax, f_ax = d.axes("C"), d.axes("F")
    h_ax, w_ax = d.axes("H"), d.axes("W")
    if c_ax or f_ax:
        if c_ax != f_ax:
            raise PlanError(
                f"{who}dist {_dist_str(d)} shards C over {c_ax} but F over "
                f"{f_ax} — the CF runtime pairs C and F on the same mesh "
                "axis (layer i's F-shard is layer i+1's C-shard); nearest "
                "executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        if len(c_ax) > 1:
            raise PlanError(
                f"{who}dist {_dist_str(d)} shards C/F over {c_ax} — the CF "
                "runtime supports one mesh axis per group; nearest "
                "executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        if c_ax[0] in h_ax + w_ax:
            raise PlanError(
                f"{who}dist {_dist_str(d)} puts the CF group and a spatial "
                f"dim on the same mesh axis {c_ax[0]!r} — the composed "
                "runtime needs the halo exchange and the CF collective on "
                "different axes; nearest executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        unknown = set(d.dims) - {"N", "C", "F", "H", "W"}
        if unknown:
            raise PlanError(f"{who}dist {_dist_str(d)} shards non-CNN dims "
                            f"{unknown}")
        return CFSharding(batch_axes=d.axes("N"), cf_axis=c_ax[0],
                          h_axis=_spatial_axis(h_ax),
                          w_axis=_spatial_axis(w_ax))
    unknown = set(d.dims) - {"N", "H", "W"}
    if unknown:
        raise PlanError(f"{who}dist {_dist_str(d)} shards non-CNN dims "
                        f"{unknown}; nearest executable demotion: "
                        f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
    return ConvSharding(batch_axes=d.axes("N"),
                        h_axis=_spatial_axis(h_ax),
                        w_axis=_spatial_axis(w_ax))


def is_executable(d: Dist, mesh_shape: Mapping[str, int]) -> bool:
    try:
        dist_to_sharding(d, mesh_shape)
        return True
    except PlanError:
        return False


def executable_candidates(layer: ConvLayer, mesh_shape: Mapping[str, int],
                          allow_w_split: bool = True,
                          allow_channel_filter: bool = True) -> list[Dist]:
    """The §V-C candidate set restricted to runtime-executable dists.

    Channel/filter candidates (§III-D) are included by default now that
    core.channel_conv executes them — including CF x spatial compositions
    (CF on one axis, H/W on others) and spatial dims split over *products*
    of mesh axes (core.halo), the hybrids 16x16 meshes need.  The few
    combinations the runtime still rejects (C and F on different axes,
    multi-axis CF groups) are filtered out here, so the solver only ever
    sees what it can run.  Never empty: a fully replicated layer is always
    executable (the solver then pays pure redundancy for it, which
    correctly prices it out whenever any parallel candidate exists).
    """
    out = [d for d in candidate_dists(
               layer, mesh_shape,
               allow_channel_filter=allow_channel_filter,
               allow_w_split=allow_w_split)
           if is_executable(d, mesh_shape)]
    return out or [Dist("replicated", {})]


def _sharding_to_dist(sh, name: str = "uniform") -> Dist:
    dims: dict[str, tuple[str, ...]] = {}
    if sh.batch_axes:
        dims["N"] = tuple(sh.batch_axes)
    if sh.h_axes:
        dims["H"] = sh.h_axes
    if sh.w_axes:
        dims["W"] = sh.w_axes
    if isinstance(sh, CFSharding) and sh.cf_axis:
        dims["C"] = dims["F"] = (sh.cf_axis,)
    return Dist(name, dims)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str
    sharding: "ConvSharding | CFSharding"
    dist: Dist | None = None      # the solved Dist (None for legacy lists)
    reshard_in: bool = False      # §III-C shuffle on this layer's input
    note: str = ""                # e.g. geometry demotion record


@dataclasses.dataclass
class NetworkPlan:
    """Executable per-layer distribution plan.

    `layers` is keyed by layer name in execution order; `default` (if set)
    answers for layer names not in the map — that is the uniform-plan
    backward-compatibility path.  `predicted` is the perf-model cost report
    from compile time (core.perfmodel.network_cost dict), if a machine was
    supplied.
    """
    layers: dict[str, LayerPlan] = dataclasses.field(default_factory=dict)
    default: ConvSharding | None = None
    predicted: dict | None = None

    # -- construction -------------------------------------------------------
    @classmethod
    def uniform(cls, sharding: ConvSharding,
                names: Sequence[str] = ()) -> "NetworkPlan":
        """The legacy single-ConvSharding configuration as a plan: every
        layer gets `sharding`, no reshard points."""
        d = _sharding_to_dist(sharding)
        return cls(layers={n: LayerPlan(n, sharding, d) for n in names},
                   default=sharding)

    @classmethod
    def from_shardings(cls, names: Sequence[str],
                       shardings: Sequence[ConvSharding]) -> "NetworkPlan":
        """Legacy per-layer ConvSharding list (meshnet.apply's old API)."""
        assert len(names) == len(shardings), (len(names), len(shardings))
        return cls(layers={n: LayerPlan(n, s)
                           for n, s in zip(names, shardings)})

    @classmethod
    def of(cls, obj) -> "NetworkPlan":
        """Normalize NetworkPlan | ConvSharding | None into a plan."""
        if isinstance(obj, NetworkPlan):
            return obj
        if obj is None:
            return cls.uniform(ConvSharding())
        if isinstance(obj, (ConvSharding, CFSharding)):
            return cls.uniform(obj)
        raise TypeError(f"cannot build a NetworkPlan from {type(obj)}")

    # -- queries ------------------------------------------------------------
    def sharding(self, name: str) -> "ConvSharding | CFSharding":
        lp = self.layers.get(name)
        if lp is not None:
            return lp.sharding
        if self.default is not None:
            return self.default
        raise PlanError(f"plan has no entry for layer {name!r} "
                        f"(knows {list(self.layers)[:8]}...)")

    @property
    def n_reshards(self) -> int:
        return sum(lp.reshard_in for lp in self.layers.values())

    def input_spec(self, name: str, h: int, w: int, k: int, s: int,
                   mesh=None) -> P:
        """Placement spec for the NHWC tensor feeding layer `name`, with the
        geometry fit applied (so hosts can device_put the batch directly)."""
        return self.sharding(name).fit(h, w, k, s, mesh).x_spec()

    # -- execution ----------------------------------------------------------
    def reshard(self, x, name: str, mesh=None):
        """Apply the §III-C shuffle entering layer `name`: a sharding
        constraint at the distribution change, which GSPMD lowers to the
        redistribution collective the perf model charged as Shuffle."""
        lp = self.layers.get(name)
        if lp is None or not lp.reshard_in or mesh is None:
            return x
        return lax.with_sharding_constraint(
            x, NamedSharding(mesh, lp.sharding.x_spec()))

    # -- reporting ----------------------------------------------------------
    def describe(self) -> str:
        rows = []
        for lp in self.layers.values():
            tag = "shuffle <- " if lp.reshard_in else ""
            sh = lp.sharding
            parts = []
            if sh.batch_axes:
                parts.append(f"N:{','.join(sh.batch_axes)}")
            if sh.h_axes:
                parts.append(f"H:{'x'.join(sh.h_axes)}")
            if sh.w_axes:
                parts.append(f"W:{'x'.join(sh.w_axes)}")
            if isinstance(sh, CFSharding) and sh.cf_axis:
                parts.append(f"CF:{sh.cf_axis}({sh.mode})")
            lay = " ".join(parts) or "replicated"
            note = f"   [{lp.note}]" if lp.note else ""
            rows.append(f"  {lp.name:20s} {tag}{lay}{note}")
        head = [f"NetworkPlan: {len(self.layers)} layers, "
                f"{self.n_reshards} reshard points"]
        if self.predicted is not None:
            head.append(
                f"  predicted step: {self.predicted['total']*1e3:.3f} ms "
                f"(fp {self.predicted['fp']*1e3:.3f} + "
                f"shuffle {self.predicted['shuffle']*1e3:.3f} + "
                f"bp {self.predicted['bp']*1e3:.3f})")
        return "\n".join(head + rows)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _mesh_shape(mesh) -> dict[str, int]:
    if mesh is None:
        return {}
    if isinstance(mesh, Mapping):
        return dict(mesh)
    return dict(mesh.shape)


def _geom_mesh(mesh_shape: Mapping[str, int]):
    """ConvSharding.fit only reads dict(mesh.shape)."""
    return types.SimpleNamespace(shape=dict(mesh_shape)) if mesh_shape \
        else None


def compile_plan(dists: Mapping[str, Dist] | Sequence[Dist],
                 specs: Sequence[ConvLayer], mesh=None, *,
                 graph=None, machine: Machine | None = None,
                 table: EmpiricalTable | None = None,
                 overlap: bool = True,
                 cost_specs: Sequence[ConvLayer] | None = None
                 ) -> NetworkPlan:
    """Lower a solved distribution map into an executable NetworkPlan.

    dists:   {layer name: Dist} (solve_dag) or a Dist per spec (solve_line).
    specs:   ConvLayers in execution order (the geometry to validate against).
    graph:   optional nx.DiGraph: reshard points are detected against actual
             predecessors instead of list order (branchy networks).
    machine: if given, attach the §V-B cost report under the *compiled*
             (post-demotion) distributions, evaluated over `cost_specs`
             (default: `specs`) — branchy networks pass their main path so
             side branches are not costed as line continuations.
    """
    mesh_shape = _mesh_shape(mesh)
    gm = _geom_mesh(mesh_shape)
    if not isinstance(dists, Mapping):
        assert len(dists) == len(specs), (len(dists), len(specs))
        dists = {l.name: d for l, d in zip(specs, dists)}

    compiled: dict[str, LayerPlan] = {}
    final: dict[str, Dist] = {}
    for i, spec in enumerate(specs):
        if spec.name not in dists:
            raise PlanError(f"no solved dist for layer {spec.name!r}")
        d = normalize_dist(dists[spec.name], mesh_shape)
        sh = dist_to_sharding(d, mesh_shape, layer=spec.name)
        n_ways = d.ways("N", mesh_shape)
        if spec.n % n_ways:
            raise PlanError(
                f"layer {spec.name!r}: N={spec.n} not divisible by "
                f"{n_ways}-way {_dist_str(d)}; nearest executable "
                f"demotion: {_dist_str(_demoted(d, set(d.dims) - {'N'}))}")
        note = ""
        # the §III-A geometry fit applies to both descriptor kinds now that
        # CFSharding may compose spatial axes: record any demotion so the
        # executed and costed plans stay identical.
        fitted = sh.fit(spec.h, spec.w, spec.k, spec.s, gm) if gm else sh
        if fitted != sh:
            dropped = [ax for ax in ("h_axis", "w_axis")
                       if getattr(sh, ax) and not getattr(fitted, ax)]
            note = (f"demoted {'/'.join(dropped)}: "
                    f"{spec.h}x{spec.w} shard vs k={spec.k},s={spec.s}")
            sh = fitted
            d = _sharding_to_dist(sh, d.name + "-demoted")
        if isinstance(sh, CFSharding):
            if not sh.fits_channels(spec.c, spec.f, mesh_shape):
                # the CF edge case: channel counts must divide the mesh
                # axis; demote to the sample/spatial remainder at compile
                # time and record it so the cost report stays honest.
                ways = mesh_shape.get(sh.cf_axis, 1)
                note = (note + "; " if note else "") + (
                    f"demoted C/F: {spec.c}->{spec.f} channels vs "
                    f"{ways}-way {sh.cf_axis}")
                d = _demoted(d, {"N", "H", "W"})
                sh = dist_to_sharding(d, mesh_shape, layer=spec.name)
            else:
                # per-layer 'filter' vs 'channel' pick: the runtime executes
                # whichever §III-D collective moves fewer words — AG(x) vs
                # RS(y) at the sub-mesh shard shapes (perfmodel).
                sh = dataclasses.replace(
                    sh, mode=cf_mode_for(spec, d, mesh_shape))
        if graph is not None:
            preds = [final[p] for p in graph.predecessors(spec.name)
                     if p in final]
            reshard = any(not p.same_as(d) for p in preds)
        else:
            prev = final.get(specs[i - 1].name) if i else None
            reshard = prev is not None and not prev.same_as(d)
        compiled[spec.name] = LayerPlan(spec.name, sh, d,
                                        reshard_in=reshard, note=note)
        final[spec.name] = d

    predicted = None
    if machine is not None and mesh_shape:
        cs = list(cost_specs if cost_specs is not None else specs)
        predicted = network_cost(machine, cs, [final[l.name] for l in cs],
                                 mesh_shape, table, overlap)
    return NetworkPlan(layers=compiled, predicted=predicted)


# ---------------------------------------------------------------------------
# solve + compile in one step
# ---------------------------------------------------------------------------

def plan_line(machine: Machine, specs: Sequence[ConvLayer], mesh, *,
              table: EmpiricalTable | None = None, overlap: bool = True,
              allow_w_split: bool = True,
              allow_channel_filter: bool = True) -> NetworkPlan:
    """Line networks (meshnet): §V-C shortest path over executable
    candidates (sample, spatial and channel/filter), compiled to a
    NetworkPlan."""
    mesh_shape = _mesh_shape(mesh)
    cands = [executable_candidates(l, mesh_shape, allow_w_split,
                                   allow_channel_filter)
             for l in specs]
    res = solve_line(machine, specs, cands, mesh_shape, table, overlap)
    return compile_plan(res.dists, specs, mesh, machine=machine,
                        table=table, overlap=overlap)


def plan_graph(machine: Machine, graph, specs: Sequence[ConvLayer], mesh, *,
               table: EmpiricalTable | None = None,
               overlap: bool = True,
               allow_w_split: bool = True,
               allow_channel_filter: bool = True) -> NetworkPlan:
    """Branchy networks (ResNet): §V-C longest-path-first over the DAG.

    `specs` fixes the execution/validation order and may be a subset of the
    graph (e.g. the main path); side-branch nodes present in the graph but
    not in `specs` are compiled too, ordered after their predecessors.
    """
    mesh_shape = _mesh_shape(mesh)
    dists = solve_dag(machine, graph, mesh_shape, table, overlap,
                      candidate_fn=lambda l: executable_candidates(
                          l, mesh_shape, allow_w_split,
                          allow_channel_filter))
    names = [l.name for l in specs]
    extra = [n for n in graph.nodes if n not in set(names)]
    all_specs = list(specs) + [graph.nodes[n]["layer"] for n in extra]
    return compile_plan(dists, all_specs, mesh, graph=graph,
                        machine=machine, table=table, overlap=overlap,
                        cost_specs=specs)
