"""Strategy-to-execution plan compiler (paper §V-C output -> runtime).

`strategy.solve_dag` / `solve_line` answer the paper's optimization problem
with a `{layer name: Dist}` map — a *mathematical* object.  This module
lowers that map into a `NetworkPlan` the models execute:

  * each layer's `Dist` becomes the runtime sharding descriptor that drives
    execution: a `ConvSharding` for sample/spatial distributions (the
    halo-exchange conv/pool/BN implementations, core.spatial_conv) or a
    `CFSharding` for channel/filter distributions (§III-D — the
    row/column-parallel conv in core.channel_conv, the paper's "hidden
    dimension" parallelism for late, channel-heavy layers whose spatial
    extents are too small to split);
  * a distribution change between consecutive layers becomes an explicit
    reshard point — the paper's Shuffle(D_i, D_j) (§III-C) — lowered to
    ``lax.with_sharding_constraint`` so GSPMD materializes the all-to-all
    exactly where the optimizer paid for it;
  * every layer is validated against its geometry (the `ConvSharding.fit`
    edge cases, §III-A): a distribution the runtime would demote (spatial
    shard smaller than the kernel, non-divisible extents, channel counts
    that do not divide the CF mesh axis) is demoted at *compile* time and
    recorded, so the perf-model prediction stays honest;
  * mesh axes of size 1 are dropped (they provide no parallelism), which
    makes a plan solved on a 1x1 mesh execute the exact single-device code
    path — the oracle-equivalence contract the tests pin down;
  * the compiled plan carries a predicted cost report (core.perfmodel) so
    measured step time can be cross-checked against the model
    (benchmarks/strategy_exec.py).

A `NetworkPlan` built with `NetworkPlan.uniform(conv_sharding)` reproduces
the legacy one-`ConvSharding`-for-every-layer behavior bit for bit, which is
how existing callers keep working.

Mixed plans compose freely: a solved network can open with hybrid
sample+spatial layers, switch late layers to channel/filter parallelism
when the solver prices the halo above the reduce-scatter, and close with a
sample-parallel head — each transition is one recorded reshard point.
`examples/quickstart.py` demos such a mixed spatial+CF plan end to end.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Mapping, Sequence

import jax
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import trace as trace_lib
from repro.core.channel_conv import CFSharding, chunks_decision
from repro.core.distribution import Dist
from repro.core.halo import pinned as halo_pinned
from repro.core.perfmodel import (ConvLayer, EmpiricalTable, Machine,
                                  cf_mode_for, layer_collectives,
                                  layer_memory, network_cost,
                                  network_memory, shuffle_time)
from repro.core.spatial_conv import ConvSharding
from repro.core.strategy import (CapacityError, candidate_dists,
                                 parse_search, solve_dag, solve_dag_beam,
                                 solve_hillclimb, solve_line)
from repro.utils import human_bytes


class PlanError(ValueError):
    """A distribution map cannot be lowered to an executable plan.

    Messages name the offending layer (when known) and dist, and suggest
    the nearest executable demotion so callers can fix their map."""


PLAN_SCHEMA = "repro/plan@1"


# ---------------------------------------------------------------------------
# Dist -> ConvSharding lowering
# ---------------------------------------------------------------------------

def normalize_dist(d: Dist, mesh_shape: Mapping[str, int]) -> Dist:
    """Drop mesh axes of size 1 — they contribute no parallelism, and
    dropping them lets size-1 meshes take the dense single-device path."""
    dims = {k: tuple(a for a in axes if mesh_shape.get(a, 1) > 1)
            for k, axes in d.dims.items()}
    dims = {k: v for k, v in dims.items() if v}
    return Dist(d.name, dims)


def _demoted(d: Dist, keep: set[str]) -> Dist:
    """The nearest executable demotion: `d` restricted to dims in `keep`."""
    return Dist(d.name + "-demoted",
                {k: v for k, v in d.dims.items() if k in keep})


def _dist_str(d: Dist) -> str:
    dims = " ".join(f"{k}:{','.join(v)}" for k, v in d.dims.items())
    return f"{d.name!r} ({dims or 'replicated'})"


def _spatial_axis(axes: tuple[str, ...]):
    """A spatial dim's runtime axis spec: None / bare axis / product tuple
    (core.halo's linearized product-axis convention for multi-axis splits,
    the 16x16-mesh case)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def dist_to_sharding(d: Dist, mesh_shape: Mapping[str, int],
                     layer: str | None = None):
    """Lower a Dist to its runtime sharding descriptor, or raise PlanError.

    Sample (N) and spatial distributions — H and/or W, each over one mesh
    axis or a *product* of axes (core.halo) — lower to `ConvSharding`;
    channel/filter distributions (§III-D, C and F paired on one mesh axis),
    optionally composed with spatial sharding on different axes, lower to
    `CFSharding` (core.channel_conv).  `layer` (when known) names the
    offending layer in diagnostics.
    """
    d = normalize_dist(d, mesh_shape)
    who = f"layer {layer!r}: " if layer else ""
    c_ax, f_ax = d.axes("C"), d.axes("F")
    h_ax, w_ax = d.axes("H"), d.axes("W")
    if c_ax or f_ax:
        if c_ax != f_ax:
            raise PlanError(
                f"{who}dist {_dist_str(d)} shards C over {c_ax} but F over "
                f"{f_ax} — the CF runtime pairs C and F on the same mesh "
                "axis (layer i's F-shard is layer i+1's C-shard); nearest "
                "executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        if len(c_ax) > 1:
            raise PlanError(
                f"{who}dist {_dist_str(d)} shards C/F over {c_ax} — the CF "
                "runtime supports one mesh axis per group; nearest "
                "executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        if c_ax[0] in h_ax + w_ax:
            raise PlanError(
                f"{who}dist {_dist_str(d)} puts the CF group and a spatial "
                f"dim on the same mesh axis {c_ax[0]!r} — the composed "
                "runtime needs the halo exchange and the CF collective on "
                "different axes; nearest executable demotion: "
                f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
        unknown = set(d.dims) - {"N", "C", "F", "H", "W"}
        if unknown:
            raise PlanError(f"{who}dist {_dist_str(d)} shards non-CNN dims "
                            f"{unknown}")
        return CFSharding(batch_axes=d.axes("N"), cf_axis=c_ax[0],
                          h_axis=_spatial_axis(h_ax),
                          w_axis=_spatial_axis(w_ax))
    unknown = set(d.dims) - {"N", "H", "W"}
    if unknown:
        raise PlanError(f"{who}dist {_dist_str(d)} shards non-CNN dims "
                        f"{unknown}; nearest executable demotion: "
                        f"{_dist_str(_demoted(d, {'N', 'H', 'W'}))}")
    return ConvSharding(batch_axes=d.axes("N"),
                        h_axis=_spatial_axis(h_ax),
                        w_axis=_spatial_axis(w_ax))


def is_executable(d: Dist, mesh_shape: Mapping[str, int]) -> bool:
    try:
        dist_to_sharding(d, mesh_shape)
        return True
    except PlanError:
        return False


def executable_candidates(layer: ConvLayer, mesh_shape: Mapping[str, int],
                          allow_w_split: bool = True,
                          allow_channel_filter: bool = True,
                          wide: bool = False) -> list[Dist]:
    """The §V-C candidate set restricted to runtime-executable dists.

    Channel/filter candidates (§III-D) are included by default now that
    core.channel_conv executes them — including CF x spatial compositions
    (CF on one axis, H/W on others) and spatial dims split over *products*
    of mesh axes (core.halo), the hybrids 16x16 meshes need.  The few
    combinations the runtime still rejects (C and F on different axes,
    multi-axis CF groups) are filtered out here, so the solver only ever
    sees what it can run.  Never empty: a fully replicated layer is always
    executable (the solver then pays pure redundancy for it, which
    correctly prices it out whenever any parallel candidate exists).

    `wide` forwards to candidate_dists: the beam/hillclimb search space
    also lets mesh axes go unassigned (partial replication) — every such
    dist still lowers through dist_to_sharding, so is_executable keeps the
    widened set honest.
    """
    out = [d for d in candidate_dists(
               layer, mesh_shape,
               allow_channel_filter=allow_channel_filter,
               allow_w_split=allow_w_split,
               wide=wide)
           if is_executable(d, mesh_shape)]
    return out or [Dist("replicated", {})]


def _sharding_to_dist(sh, name: str = "uniform") -> Dist:
    dims: dict[str, tuple[str, ...]] = {}
    if sh.batch_axes:
        dims["N"] = tuple(sh.batch_axes)
    if sh.h_axes:
        dims["H"] = sh.h_axes
    if sh.w_axes:
        dims["W"] = sh.w_axes
    if isinstance(sh, CFSharding) and sh.cf_axis:
        dims["C"] = dims["F"] = (sh.cf_axis,)
    return Dist(name, dims)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str
    sharding: "ConvSharding | CFSharding"
    dist: Dist | None = None      # the COMPILED Dist (None for legacy lists)
    reshard_in: bool = False      # §III-C shuffle on this layer's input
    note: str = ""                # e.g. geometry demotion record
    # the pre-demotion solved Dist, recorded only when compile_plan demoted
    # it — the plan linter re-derives whether the demotion was load-bearing
    solved: Dist | None = None


@dataclasses.dataclass
class NetworkPlan:
    """Executable per-layer distribution plan.

    `layers` is keyed by layer name in execution order; `default` (if set)
    answers for layer names not in the map — that is the uniform-plan
    backward-compatibility path.  `predicted` is the perf-model cost report
    from compile time (core.perfmodel.network_cost dict), if a machine was
    supplied.
    """
    layers: dict[str, LayerPlan] = dataclasses.field(default_factory=dict)
    default: ConvSharding | None = None
    predicted: dict | None = None

    # -- construction -------------------------------------------------------
    @classmethod
    def uniform(cls, sharding: ConvSharding,
                names: Sequence[str] = ()) -> "NetworkPlan":
        """The legacy single-ConvSharding configuration as a plan: every
        layer gets `sharding`, no reshard points."""
        d = _sharding_to_dist(sharding)
        return cls(layers={n: LayerPlan(n, sharding, d) for n in names},
                   default=sharding)

    @classmethod
    def from_shardings(cls, names: Sequence[str],
                       shardings: Sequence[ConvSharding]) -> "NetworkPlan":
        """Legacy per-layer ConvSharding list (meshnet.apply's old API)."""
        assert len(names) == len(shardings), (len(names), len(shardings))
        return cls(layers={n: LayerPlan(n, s)
                           for n, s in zip(names, shardings)})

    @classmethod
    def of(cls, obj) -> "NetworkPlan":
        """Normalize NetworkPlan | ConvSharding | None into a plan."""
        if isinstance(obj, NetworkPlan):
            return obj
        if obj is None:
            return cls.uniform(ConvSharding())
        if isinstance(obj, (ConvSharding, CFSharding)):
            return cls.uniform(obj)
        raise TypeError(f"cannot build a NetworkPlan from {type(obj)}")

    # -- queries ------------------------------------------------------------
    def sharding(self, name: str) -> "ConvSharding | CFSharding":
        lp = self.layers.get(name)
        if lp is not None:
            return lp.sharding
        if self.default is not None:
            return self.default
        raise PlanError(f"plan has no entry for layer {name!r} "
                        f"(knows {list(self.layers)[:8]}...)")

    @property
    def n_reshards(self) -> int:
        return sum(lp.reshard_in for lp in self.layers.values())

    def input_spec(self, name: str, h: int, w: int, k: int, s: int,
                   mesh=None) -> P:
        """Placement spec for the NHWC tensor feeding layer `name`, with the
        geometry fit applied (so hosts can device_put the batch directly)."""
        return self.sharding(name).fit(h, w, k, s, mesh).x_spec()

    # -- persistence --------------------------------------------------------
    def to_spec(self, mesh=None, *, mem_limit: float | None = None,
                config_hash: str | None = None,
                calibration_fingerprint: str | None = None) -> dict:
        """The JSON-able plan record checkpoints carry (``repro/plan@1``):
        per-layer solved Dists, the mesh shape the solve ran on, the
        capacity limit it honored, and config/calibration fingerprints —
        everything an elastic restart needs to lower this plan onto a new
        mesh (plan_from_spec) or re-solve it under the same constraints."""
        layers = {}
        for lp in self.layers.values():
            d = lp.dist if lp.dist is not None \
                else _sharding_to_dist(lp.sharding, lp.name)
            layers[lp.name] = {"name": d.name,
                               "dims": {k: list(v)
                                        for k, v in d.dims.items()}}
        return {"schema": PLAN_SCHEMA,
                "layers": layers,
                "mesh": _mesh_shape(mesh) or None,
                "mem_limit": mem_limit,
                "config_hash": config_hash,
                "calibration_fingerprint": calibration_fingerprint}

    # -- execution ----------------------------------------------------------
    def reshard(self, x, name: str, mesh=None):
        """Apply the §III-C shuffle entering layer `name`: a sharding
        constraint at the distribution change, which GSPMD lowers to the
        redistribution collective the perf model charged as Shuffle."""
        lp = self.layers.get(name)
        if lp is None or not lp.reshard_in or mesh is None:
            return x
        with trace_lib.annotate("reshard"):
            y = lax.with_sharding_constraint(
                x, NamedSharding(mesh, lp.sharding.x_spec()))
            # double-buffer the reshard point: the barrier keeps the
            # redistributed tensor a distinct buffer instead of letting XLA
            # fuse the collective into the consuming layer's first op — the
            # shuffle of layer l can then run while layer l-1's tail compute
            # is still in flight (§IV-A applied between layers, not within).
            (y,) = halo_pinned((y,))
        return y

    # -- reporting ----------------------------------------------------------
    def describe(self) -> str:
        rows = []
        for lp in self.layers.values():
            tag = "shuffle <- " if lp.reshard_in else ""
            sh = lp.sharding
            parts = []
            if sh.batch_axes:
                parts.append(f"N:{','.join(sh.batch_axes)}")
            if sh.h_axes:
                parts.append(f"H:{'x'.join(sh.h_axes)}")
            if sh.w_axes:
                parts.append(f"W:{'x'.join(sh.w_axes)}")
            if isinstance(sh, CFSharding) and sh.cf_axis:
                parts.append(f"CF:{sh.cf_axis}({sh.mode})")
            lay = " ".join(parts) or "replicated"
            note = f"   [{lp.note}]" if lp.note else ""
            ov = ""
            if self.predicted is not None:
                credit = self.predicted.get("overlap_credit", {})
                if credit.get(lp.name, 0.0) > 0:
                    ov = f"   overlap -{credit[lp.name]*1e3:.3f} ms"
            rows.append(f"  {lp.name:20s} {tag}{lay}{ov}{note}")
        head = [f"NetworkPlan: {len(self.layers)} layers, "
                f"{self.n_reshards} reshard points"]
        if self.predicted is not None:
            head.append(
                f"  predicted step: {self.predicted['total']*1e3:.3f} ms "
                f"(fp {self.predicted['fp']*1e3:.3f} + "
                f"shuffle {self.predicted['shuffle']*1e3:.3f} + "
                f"bp {self.predicted['bp']*1e3:.3f})")
            credit = self.predicted.get("overlap_credit")
            if credit is not None:
                head.append(
                    f"  overlap credit: "
                    f"{sum(credit.values())*1e3:.3f} ms hidden at "
                    f"eta={self.predicted.get('overlap_eta', 1.0):.2f} "
                    f"(per-layer rows below)")
            mem = self.predicted.get("memory")
            if mem is not None:
                lim = mem.get("limit_bytes")
                head.append(
                    f"  predicted peak memory: "
                    f"{human_bytes(mem['peak_bytes'])}/device at "
                    f"{mem['peak_layer']!r}"
                    + (f" (limit {human_bytes(lim)})" if lim else ""))
        return "\n".join(head + rows)

    def audit(self, specs: Sequence[ConvLayer] | None = None, mesh=None, *,
              cfg=None, machine: Machine | None = None,
              overlap: bool = True, hlo: bool = False) -> list:
        """Static verification of this plan (repro.analysis): the pure
        plan linter always runs; with `specs`, `mesh` AND `cfg` (the
        MeshNetConfig the plan executes) the collective auditor also
        traces the AOT step — lowering only, no execution — and joins
        every collective in it against the priced inventory.  Returns the
        list of `Finding` records (render with
        repro.analysis.format_findings; error-severity findings mean the
        costed and executed plans disagree)."""
        from repro import analysis
        findings = list(analysis.lint_plan(
            self, specs=specs, mesh_shape=_mesh_shape(mesh) or None))
        if cfg is not None and mesh is not None and specs is not None:
            findings += analysis.audit_meshnet(
                self, specs, cfg, mesh, machine=machine, overlap=overlap,
                hlo=hlo)
        return findings

    def attribution_report(self, trace, *, tol: float = 5.0) -> dict:
        """Join a measured StepTrace (core.trace) against this plan's
        perf-model predictions, per layer and per cost term.

        Per layer: predicted fwd (layer_cost fp + the incoming shuffle) and
        bwd (bpx + bpw + bpa) seconds next to the trace's measured isolated
        fwd/bwd, with ratio = measured / predicted; layers whose ratio
        exceeds `tol` in either direction are flagged.

        Per term: the model's cost decomposition {fp_compute, fp_comm,
        bp_compute, bp_comm, bpa, shuffle} each gets a drift estimate — the
        predicted-seconds-weighted mean of the per-layer measured/predicted
        ratio in that term's direction (fwd or bwd).  The measurement only
        resolves whole fwd/bwd segments, so a term's drift is the layer
        ratio weighted by how much of the prediction that term carries:
        terms that dominate the predicted time in layers that drift most
        are named as `worst_term` — the §V model-vs-measured mystery
        decomposed into named per-term suspects.

        Requires a plan compiled with a `machine` (predicted cost report).
        """
        if not self.predicted or "layer_costs" not in self.predicted:
            raise PlanError("attribution needs a plan compiled with a "
                            "`machine` (no predicted layer costs attached)")
        costs = self.predicted["layer_costs"]
        shuf = self.predicted.get("shuffle_per_layer", {})
        missing = [n for n in costs if n not in trace.layers]
        if missing:
            raise PlanError(f"trace has no measurement for plan layers "
                            f"{missing} (knows {list(trace.layers)[:8]}...)")

        per_layer: dict[str, dict] = {}
        flagged: list[str] = []
        for name, c in costs.items():
            # float() everywhere: perf-model terms may be numpy scalars,
            # and the report must stay json.dump-able as-is
            pf = float(c.fp + shuf.get(name, 0.0))
            pb = float(c.bpx + c.bpw + c.bpa)
            mf = float(trace.layers[name]["fwd_s"])
            mb = float(trace.layers[name]["bwd_s"])
            ratio = (mf + mb) / (pf + pb) if pf + pb > 0 else float("nan")
            flag = bool(ratio == ratio
                        and (ratio > tol or ratio < 1.0 / tol))
            if flag:
                flagged.append(name)
            per_layer[name] = {
                "predicted_fwd_s": pf, "measured_fwd_s": mf,
                "predicted_bwd_s": pb, "measured_bwd_s": mb,
                "ratio_total": ratio, "flagged": flag}

        # per-term drift: terms split by the direction they live in
        def terms_of(name):
            c = costs[name]
            return {"fp_compute": (float(c.fp_compute), "f"),
                    "fp_comm": (float(c.fp - c.fp_compute + c.fp_saved),
                                "f"),
                    "shuffle": (float(shuf.get(name, 0.0)), "f"),
                    "bp_compute": (float(c.bp_compute), "b"),
                    "bp_comm": (float(c.bpx + c.bpw - c.bp_compute
                                      + c.bp_saved), "b"),
                    "bpa": (float(c.bpa), "b")}

        acc: dict[str, list[float]] = {}
        for name in costs:
            r = per_layer[name]
            dir_ratio = {
                "f": (r["measured_fwd_s"] / r["predicted_fwd_s"]
                      if r["predicted_fwd_s"] > 0 else None),
                "b": (r["measured_bwd_s"] / r["predicted_bwd_s"]
                      if r["predicted_bwd_s"] > 0 else None)}
            for term, (w, d) in terms_of(name).items():
                if w > 0 and dir_ratio[d] is not None:
                    s = acc.setdefault(term, [0.0, 0.0])
                    s[0] += w * dir_ratio[d]
                    s[1] += w
        terms = {t: {"drift": s[0] / s[1], "predicted_s": s[1]}
                 for t, s in acc.items() if s[1] > 0}
        worst = None
        if terms:
            import math
            worst = max(terms, key=lambda t: abs(math.log(
                max(terms[t]["drift"], 1e-12))))

        pred_total = sum(r["predicted_fwd_s"] + r["predicted_bwd_s"]
                         for r in per_layer.values())
        meas_total = sum(r["measured_fwd_s"] + r["measured_bwd_s"]
                         for r in per_layer.values())
        return {"schema": "repro/attribution@1",
                "tolerance": tol,
                "per_layer": per_layer,
                "flagged": flagged,
                "terms": terms,
                "worst_term": worst,
                "totals": {"predicted_s": pred_total,
                           "measured_s": meas_total,
                           "ratio": (meas_total / pred_total
                                     if pred_total > 0 else float("nan")),
                           "step_measured_s": trace.step["fwd_bwd_s"]}}


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _mesh_shape(mesh) -> dict[str, int]:
    if mesh is None:
        return {}
    if isinstance(mesh, Mapping):
        return dict(mesh)
    return dict(mesh.shape)


def _geom_mesh(mesh_shape: Mapping[str, int]):
    """ConvSharding.fit only reads dict(mesh.shape)."""
    return types.SimpleNamespace(shape=dict(mesh_shape)) if mesh_shape \
        else None


def compile_plan(dists: Mapping[str, Dist] | Sequence[Dist],
                 specs: Sequence[ConvLayer], mesh=None, *,
                 graph=None, machine: Machine | None = None,
                 table: EmpiricalTable | None = None,
                 overlap: bool = True,
                 cost_specs: Sequence[ConvLayer] | None = None,
                 mem_limit: float | None = None,
                 opt_words: float = 1.0
                 ) -> NetworkPlan:
    """Lower a solved distribution map into an executable NetworkPlan.

    dists:   {layer name: Dist} (solve_dag) or a Dist per spec (solve_line).
    specs:   ConvLayers in execution order (the geometry to validate against).
    graph:   optional nx.DiGraph: reshard points are detected against actual
             predecessors instead of list order (branchy networks).
    machine: if given, attach the §V-B cost report under the *compiled*
             (post-demotion) distributions, evaluated over `cost_specs`
             (default: `specs`) — branchy networks pass their main path so
             side branches are not costed as line continuations.  The report
             carries the §VI memory rollup too (predicted['memory']:
             per-layer LayerMemory breakdowns + peak_bytes/peak_layer).
    mem_limit: per-device capacity in bytes.  The compiled (post-demotion)
             plan is validated against it: a plan whose per-layer resident
             set or whole-network peak exceeds the limit raises PlanError
             with the offending layers' footprint breakdowns, and demotion
             notes record when a demotion itself violates capacity (a
             geometry demotion can *grow* the footprint — the layer falls
             back to a coarser split).
    """
    mesh_shape = _mesh_shape(mesh)
    gm = _geom_mesh(mesh_shape)
    if not isinstance(dists, Mapping):
        assert len(dists) == len(specs), (len(dists), len(specs))
        dists = {l.name: d for l, d in zip(specs, dists)}

    compiled: dict[str, LayerPlan] = {}
    final: dict[str, Dist] = {}
    cf_chunks: dict[str, int] = {}
    for i, spec in enumerate(specs):
        if spec.name not in dists:
            raise PlanError(f"no solved dist for layer {spec.name!r}")
        d = d_solved = normalize_dist(dists[spec.name], mesh_shape)
        sh = dist_to_sharding(d, mesh_shape, layer=spec.name)
        n_ways = d.ways("N", mesh_shape)
        if spec.n % n_ways:
            raise PlanError(
                f"layer {spec.name!r}: N={spec.n} not divisible by "
                f"{n_ways}-way {_dist_str(d)}; nearest executable "
                f"demotion: {_dist_str(_demoted(d, set(d.dims) - {'N'}))}")
        note = ""
        # the §III-A geometry fit applies to both descriptor kinds now that
        # CFSharding may compose spatial axes: record any demotion so the
        # executed and costed plans stay identical.
        fitted = sh.fit(spec.h, spec.w, spec.k, spec.s, gm) if gm else sh
        if fitted != sh:
            dropped = [ax for ax in ("h_axis", "w_axis")
                       if getattr(sh, ax) and not getattr(fitted, ax)]
            note = (f"demoted {'/'.join(dropped)}: "
                    f"{spec.h}x{spec.w} shard vs k={spec.k},s={spec.s}")
            sh = fitted
            d = _sharding_to_dist(sh, d.name + "-demoted")
        if isinstance(sh, CFSharding):
            if not sh.fits_channels(spec.c, spec.f, mesh_shape):
                # the CF edge case: channel counts must divide the mesh
                # axis; demote to the sample/spatial remainder at compile
                # time and record it so the cost report stays honest.
                ways = mesh_shape.get(sh.cf_axis, 1)
                note = (note + "; " if note else "") + (
                    f"demoted C/F: {spec.c}->{spec.f} channels vs "
                    f"{ways}-way {sh.cf_axis}")
                d = _demoted(d, {"N", "H", "W"})
                sh = dist_to_sharding(d, mesh_shape, layer=spec.name)
            else:
                # per-layer 'filter' vs 'channel' pick: the runtime executes
                # whichever §III-D collective moves fewer words — AG(x) vs
                # RS(y) at the sub-mesh shard shapes (perfmodel).
                sh = dataclasses.replace(
                    sh, mode=cf_mode_for(spec, d, mesh_shape))
                if sh.mode == "channel":
                    # record the calibrated chunked-CF resolution so the
                    # cost report says what the runtime will actually do
                    nblk, why = chunks_decision()
                    cf_chunks[spec.name] = nblk
                    note = (note + "; " if note else "") + (
                        f"cf chunks={nblk} ({why})")
        if note and machine is not None and mem_limit and mesh_shape:
            # a demotion falls back to a *coarser* split, so it can grow
            # the footprint past capacity — record that in the note (the
            # whole-plan validation below then raises with the breakdown)
            lm = layer_memory(machine, spec, d, mesh_shape, opt_words)
            if lm.total > mem_limit:
                note += (f"; demotion violates capacity: "
                         f"{human_bytes(lm.total)} > "
                         f"{human_bytes(mem_limit)}/device "
                         f"({lm.breakdown()})")
        if graph is not None:
            preds = [final[p] for p in graph.predecessors(spec.name)
                     if p in final]
            reshard = any(not p.same_as(d) for p in preds)
        else:
            prev = final.get(specs[i - 1].name) if i else None
            reshard = prev is not None and not prev.same_as(d)
        compiled[spec.name] = LayerPlan(
            spec.name, sh, d, reshard_in=reshard, note=note,
            solved=None if d_solved.same_as(d) else d_solved)
        final[spec.name] = d

    predicted = None
    if mem_limit and machine is None:
        raise PlanError("mem_limit validation needs a `machine` (the memory "
                        "model's wordsize and accounting live there)")
    if machine is not None and mesh_shape:
        cs = list(cost_specs if cost_specs is not None else specs)
        predicted = network_cost(machine, cs, [final[l.name] for l in cs],
                                 mesh_shape, table, overlap)
        # per-layer η-scaled overlap credit: the seconds of communication
        # the schedule is credited with hiding (0 when nothing overlaps),
        # surfaced so describe() can report the latency-hiding budget.
        predicted["overlap_eta"] = machine.overlap_eta if overlap else 0.0
        predicted["overlap_credit"] = {
            l.name: c.overlap_credit
            for l, c in zip(cs, predicted["per_layer"])}
        # name-keyed views of the per-layer cost terms — what
        # attribution_report joins against a measured StepTrace.  The
        # shuffle of transition i -> i+1 is charged to the *receiving*
        # layer (where NetworkPlan.reshard executes it).
        predicted["layer_costs"] = {
            l.name: c for l, c in zip(cs, predicted["per_layer"])}
        predicted["shuffle_per_layer"] = {cs[0].name: 0.0} if cs else {}
        for i in range(len(cs) - 1):
            predicted["shuffle_per_layer"][cs[i + 1].name] = shuffle_time(
                machine, cs[i], final[cs[i].name], final[cs[i + 1].name],
                mesh_shape, table)
        # the priced-collective inventory (perfmodel.layer_collectives):
        # what the static auditor (repro.analysis) joins the traced jaxpr
        # against.  first=True: training losses grad wrt params only, so
        # the first layer's backward input halos are dead code.
        predicted["collectives_per_layer"] = {
            l.name: layer_collectives(
                machine, l, final[l.name], mesh_shape, overlap=overlap,
                first=(i == 0), channel_chunks=cf_chunks.get(l.name, 1))
            for i, l in enumerate(cs)}
        # memory rolls up over ALL compiled layers — a side branch's
        # weights and stashes are resident too, so branchy networks must
        # not escape the capacity validation just because the TIME report
        # is evaluated over the main path (cost_specs) only.
        mem = network_memory(machine, list(specs),
                             [final[l.name] for l in specs],
                             mesh_shape, opt_words)
        mem["per_layer"] = {l.name: lm
                            for l, lm in zip(specs, mem["per_layer"])}
        mem["limit_bytes"] = mem_limit
        predicted["memory"] = mem
        if mem_limit:
            over = [(name, lm) for name, lm in mem["per_layer"].items()
                    if lm.total > mem_limit]
            if over or mem["peak_bytes"] > mem_limit:
                lines = [f"  {name}: {human_bytes(lm.total)} "
                         f"({lm.breakdown()})" for name, lm in (
                             over or [(mem["peak_layer"],
                                       mem["per_layer"][mem["peak_layer"]])])]
                notes = [f"  {lp.name}: {lp.note}"
                         for lp in compiled.values()
                         if "violates capacity" in lp.note]
                raise PlanError(
                    f"compiled plan does not fit the "
                    f"{human_bytes(mem_limit)}/device memory limit: "
                    f"predicted peak {human_bytes(mem['peak_bytes'])} at "
                    f"layer {mem['peak_layer']!r}; offending per-layer "
                    f"footprints (weights/acts/halo/grads):\n"
                    + "\n".join(lines + notes))
    return NetworkPlan(layers=compiled, predicted=predicted)


# ---------------------------------------------------------------------------
# plan-spec recovery (the checkpoint round trip)
# ---------------------------------------------------------------------------

def dists_from_spec(spec: Mapping) -> dict[str, Dist]:
    """Reconstruct the solved {layer: Dist} map from a ``repro/plan@1``
    record (NetworkPlan.to_spec / a checkpoint manifest's "plan" entry)."""
    if spec.get("schema") != PLAN_SCHEMA:
        raise PlanError(f"not a {PLAN_SCHEMA} record "
                        f"(schema={spec.get('schema')!r})")
    return {name: Dist(o["name"],
                       {k: tuple(v) for k, v in o["dims"].items()})
            for name, o in spec["layers"].items()}


def plan_from_spec(spec: Mapping, specs: Sequence[ConvLayer], mesh, *,
                   machine: Machine | None = None,
                   table: EmpiricalTable | None = None,
                   overlap: bool = True,
                   mem_limit: float | None = None,
                   opt_words: float = 1.0) -> NetworkPlan:
    """Lower a stored plan spec onto `mesh` — reshard-on-restore.

    The recorded Dists name mesh *axes* ("data", "model"), not device
    counts, so the same spec lowers onto any factorization: compile_plan's
    normalization drops axes the new mesh collapsed to size 1 and the
    §III-A geometry fit demotes splits the new axis sizes no longer divide
    — both recorded in the plan notes.  Pass the checkpoint's own
    `mem_limit` to re-validate capacity on the new mesh; a spec that
    cannot fit (or that covers different layers than `specs`) raises
    PlanError, at which point the caller re-solves plan_line/plan_graph
    from scratch under the same limit.
    """
    dists = dists_from_spec(spec)
    missing = [l.name for l in specs if l.name not in dists]
    if missing:
        raise PlanError(
            f"stored plan ({PLAN_SCHEMA}) has no entry for layers "
            f"{missing} — the architecture changed; re-solve instead")
    return compile_plan(dists, specs, mesh, machine=machine, table=table,
                        overlap=overlap, mem_limit=mem_limit,
                        opt_words=opt_words)


# ---------------------------------------------------------------------------
# solve + compile in one step
# ---------------------------------------------------------------------------

# the per-layer capacity constraint (strategy.prune_by_memory) bounds each
# layer's own resident set, but the whole-network peak also accumulates the
# forward stashes of earlier layers — so a per-layer-feasible solve can
# still overflow.  plan_line/plan_graph close that gap by re-solving with a
# tightened per-layer budget, scaled by the overflow ratio, a few times.
_MEM_REFINE_ROUNDS = 4


def _solve_under_limit(solve, compile_, mem_limit):
    """Shared capacity refinement loop: `solve(per_layer_limit)` returns a
    dist map, `compile_(dists, validate)` a NetworkPlan whose predicted
    memory is inspected.  Raises PlanError/CapacityError when no fitting
    plan is found within the refinement budget."""
    if not mem_limit:
        return compile_(solve(None), None)
    limit, dists = mem_limit, None
    for _ in range(_MEM_REFINE_ROUNDS):
        try:
            dists = solve(limit)
        except CapacityError:
            if dists is None:
                raise              # infeasible at the user's own limit
            break                  # tightened past the per-layer floors
        plan = compile_(dists, None)
        if plan.predicted["memory"]["peak_bytes"] <= mem_limit:
            # the network peak bounds every per-layer resident set, so the
            # fit is already proven — record the limit, no recompile
            plan.predicted["memory"]["limit_bytes"] = mem_limit
            return plan
        # overflow: the stash accumulation ate the headroom — tighten the
        # per-layer budget proportionally and re-solve
        limit *= 0.9 * mem_limit / plan.predicted["memory"]["peak_bytes"]
    return compile_(dists, mem_limit)          # raises with the breakdown


def plan_line(machine: Machine, specs: Sequence[ConvLayer], mesh, *,
              table: EmpiricalTable | None = None, overlap: bool = True,
              allow_w_split: bool = True,
              allow_channel_filter: bool = True,
              mem_limit: float | None = None,
              opt_words: float = 1.0,
              search: str = "greedy") -> NetworkPlan:
    """Line networks (meshnet): §V-C shortest path over executable
    candidates (sample, spatial and channel/filter), compiled to a
    NetworkPlan.

    `mem_limit` (bytes/device) makes the solve memory-aware: min-time
    subject to every layer's resident set AND the whole-network peak
    (stash accumulation included) fitting — the §VI Table-2 capability.

    `search` widens the space beyond the paper's heuristic: "greedy" is
    the default one-target-per-axis DP; "beam[:N]" runs the same exact
    line DP over the *wide* candidate set (axes may go unassigned), a
    strict superset, so its predicted optimum is never worse; "hillclimb"
    is the stochastic local-search baseline over the same wide set.
    """
    mode, width = parse_search(search)
    mesh_shape = _mesh_shape(mesh)
    cands = [executable_candidates(l, mesh_shape, allow_w_split,
                                   allow_channel_filter,
                                   wide=mode != "greedy")
             for l in specs]

    def solve(limit):
        if mode == "hillclimb":
            return solve_hillclimb(machine, specs, cands, mesh_shape, table,
                                   overlap, mem_limit=limit,
                                   opt_words=opt_words).dists
        # a line's beam search IS the exact DP (solve_line); the widened
        # candidate set is where beam mode's advantage lives
        return solve_line(machine, specs, cands, mesh_shape, table, overlap,
                          mem_limit=limit, opt_words=opt_words).dists

    def compile_(dists, validate_limit):
        return compile_plan(dists, specs, mesh, machine=machine,
                            table=table, overlap=overlap,
                            mem_limit=validate_limit, opt_words=opt_words)

    return _solve_under_limit(solve, compile_, mem_limit)


def plan_graph(machine: Machine, graph, specs: Sequence[ConvLayer], mesh, *,
               table: EmpiricalTable | None = None,
               overlap: bool = True,
               allow_w_split: bool = True,
               allow_channel_filter: bool = True,
               mem_limit: float | None = None,
               opt_words: float = 1.0,
               search: str = "greedy") -> NetworkPlan:
    """Branchy networks (ResNet): §V-C longest-path-first over the DAG.

    `specs` fixes the execution/validation order and may be a subset of the
    graph (e.g. the main path); side-branch nodes present in the graph but
    not in `specs` are compiled too, ordered after their predecessors.
    `mem_limit` applies the same capacity constraint as plan_line.

    `search` = "beam[:N]" replaces longest-path-first with the global
    reshard-cost-aware beam DP (strategy.solve_dag_beam) over the wide
    candidate set — every cross edge between paths is priced, not just
    the fixed paths'.  "hillclimb" runs the stochastic baseline over the
    DAG's full edge set.
    """
    mode, width = parse_search(search)
    mesh_shape = _mesh_shape(mesh)
    names = [l.name for l in specs]
    extra = [n for n in graph.nodes if n not in set(names)]
    all_specs = list(specs) + [graph.nodes[n]["layer"] for n in extra]

    def candidate_fn(l):
        return executable_candidates(l, mesh_shape, allow_w_split,
                                     allow_channel_filter,
                                     wide=mode != "greedy")

    def solve(limit):
        if mode == "beam":
            return solve_dag_beam(machine, graph, mesh_shape, table,
                                  overlap, candidate_fn=candidate_fn,
                                  mem_limit=limit, opt_words=opt_words,
                                  width=width)
        if mode == "hillclimb":
            order = list(graph.nodes)
            pos = {n: i for i, n in enumerate(order)}
            layers = [graph.nodes[n]["layer"] for n in order]
            res = solve_hillclimb(
                machine, layers, [candidate_fn(l) for l in layers],
                mesh_shape, table, overlap,
                edges=[(pos[u], pos[v]) for u, v in graph.edges],
                mem_limit=limit, opt_words=opt_words)
            return {n: d for n, d in zip(order, res.dists)}
        return solve_dag(machine, graph, mesh_shape, table, overlap,
                         candidate_fn=candidate_fn,
                         mem_limit=limit, opt_words=opt_words)

    def compile_(dists, validate_limit):
        return compile_plan(dists, all_specs, mesh, graph=graph,
                            machine=machine, table=table, overlap=overlap,
                            cost_specs=specs, mem_limit=validate_limit,
                            opt_words=opt_words)

    return _solve_under_limit(solve, compile_, mem_limit)
