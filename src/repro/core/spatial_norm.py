"""Batch normalization under spatial decomposition (paper §III-B).

The paper: "Both purely local batch normalization and a variant that
aggregates over the spatial distribution of a sample are easy to implement."
We provide three statistics scopes:

  'local'   per-shard statistics (the paper's default; zero communication)
  'spatial' aggregate over the spatial shards of a sample (psum over the
            model axis) — the paper's proposed variant
  'global'  aggregate over all batch+spatial shards (true global BN)

All scopes share parameters (gamma/beta replicated).  Training-mode only
(running statistics are maintained by the train loop state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import trace as trace_lib
from repro.core.spatial_conv import ConvSharding
from repro.utils import shard_map


def _stats(x, axes):
    n = 1
    for a in axes:
        n *= x.shape[a]
    s = jnp.sum(x, axes)
    ss = jnp.sum(jnp.square(x), axes)
    return s, ss, n


def batch_norm(x, gamma, beta, *, sharding: ConvSharding, mesh=None,
               scope: str = "local", eps: float = 1e-5):
    """BN over (N, H, W) of an NHWC tensor with the given statistics scope."""
    reduce_axes = (0, 1, 2)

    if scope == "local" or not sharding.is_spatial:
        def local_fn(x):
            s, ss, n = _stats(x.astype(jnp.float32), reduce_axes)
            mean = s / n
            var = ss / n - jnp.square(mean)
            inv = lax.rsqrt(var + eps)
            return ((x - mean.astype(x.dtype)) * inv.astype(x.dtype))
        if scope == "local" and sharding.is_spatial and mesh is not None:
            spec = sharding.x_spec()
            y = shard_map(local_fn, mesh=mesh, in_specs=(spec,),
                          out_specs=spec)(x)
        else:
            y = local_fn(x)
        return y * gamma + beta

    comm_axes: tuple[str, ...]
    if scope == "spatial":
        comm_axes = sharding.spatial_axes
    elif scope == "global":
        comm_axes = tuple(sharding.batch_axes or ()) + sharding.spatial_axes
    else:
        raise ValueError(f"unknown BN scope {scope!r}")

    mesh = mesh or jax.sharding.get_abstract_mesh()

    def fn(x):
        s, ss, n = _stats(x.astype(jnp.float32), reduce_axes)
        with trace_lib.annotate("bn_collective"):
            s = lax.psum(s, comm_axes)
            ss = lax.psum(ss, comm_axes)
        n = n * functools.reduce(
            lambda a, b: a * b, (dict(mesh.shape)[ax] for ax in comm_axes), 1)
        mean = s / n
        var = ss / n - jnp.square(mean)
        inv = lax.rsqrt(var + eps)
        return (x - mean.astype(x.dtype)) * inv.astype(x.dtype)

    spec = sharding.x_spec()
    y = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(x)
    return y * gamma + beta
