"""Sequence-parallel state-space recurrence — the paper's halo exchange in
its purest transformer-era form.

A (chunked) SSM layer on a sequence-sharded tensor needs exactly one piece of
remote data per shard: the recurrent state flowing in across the left
boundary — a single (B, heads, d_head, d_state) tensor.  That is a
constant-width halo, the direct analogue of the paper's O-row conv halo.

Each shard locally reduces its chunk to a (decay, state-contribution)
summary (A, S); the state entering shard p is the *exclusive prefix* under
the associative combine (x before y):

    (A_x, S_x) ∘ (A_y, S_y) = (A_x·A_y,  S_x·A_y + S_y)

computed across the mesh axis in ceil(log2 P) ppermute rounds (Hillis-Steele
over ICI neighbors).  The paper's 1-D conv halo costs one SR(n); this costs
log2(P)·SR(n) once per layer — still negligible next to the matmul work.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def seq_prefix_state(a_total, s_local, axis_name: str, axis_size: int):
    """Exclusive prefix combine of per-shard (decay, state) summaries.

    a_total: total decay across the local chunk, broadcastable to s_local
             (e.g. (B, H, 1, 1)).
    s_local: state contributed by the local chunk alone (B, H, dh, ds).
    Returns s_in — the recurrent state entering this shard (zeros on shard 0).
    """
    a_inc, s_inc = a_total, s_local
    d = 1
    while d < axis_size:
        perm = [(i, i + d) for i in range(axis_size - d)]
        a_recv = lax.ppermute(a_inc, axis_name, perm)   # prefix ending at i-d
        s_recv = lax.ppermute(s_inc, axis_name, perm)   # (zeros when i < d)
        idx = lax.axis_index(axis_name)
        has = idx >= d
        # S[i] <- S[i-d]·A[i] + S[i]  (use OLD a_inc before updating it)
        s_inc = jnp.where(has, s_recv * a_inc + s_inc, s_inc)
        a_inc = jnp.where(has, a_recv * a_inc, a_inc)
        d *= 2
    # exclusive shift: shard p receives the inclusive prefix of p-1;
    # shard 0 receives zeros = the correct zero initial state.
    perm = [(i, i + 1) for i in range(axis_size - 1)]
    return lax.ppermute(s_inc, axis_name, perm)
