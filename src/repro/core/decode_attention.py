"""Sequence-sharded KV-cache decoding — the paper's spatial decomposition
applied to inference.

For `decode_32k` / `long_500k` the KV cache (B, S, Hkv, D) is block-
partitioned along S over the model axis; the new token's query is replicated.
Each shard computes a partial online-softmax over its KV block; a global
log-sum-exp merge (`pmax` of the max + `psum` of rescaled numerator and
denominator) completes the exact softmax — flash-decoding mapped onto mesh
collectives.  This is what makes 500K-token batch-1 decoding *fit*: the cache
drops from hundreds of GiB to S/P tokens per chip.

Window masking makes the same routine serve sliding-window layers (only the
shards inside the window contribute; their partial sums are already masked).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.utils import shard_map

NEG_INF = -1e30


def _decode_local(q, k, v, length, *, axis_name, axis_size, scale, window,
                  softcap):
    """q: (B, 1, Hq, D) replicated; k/v: (B, Sl, Hkv, D) local cache block;
    length: () current total sequence length (the new token's position+1)."""
    b, _, hq, d = q.shape
    sl, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    idx = lax.axis_index(axis_name)
    k_off = idx * sl

    qg = q.reshape(b, hkv, g, d)  # squeeze the singleton query position
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    kpos = k_off + jnp.arange(sl)[None, :]
    mask = kpos < length                      # only filled cache positions
    if window is not None:
        mask &= (length - 1 - kpos) < window  # sliding window around the tip
    s = jnp.where(mask[:, None, None], s, NEG_INF)

    m = jnp.max(s, axis=-1)                               # (B, Hkv, G)
    m_glob = lax.pmax(m, axis_name)
    p = jnp.exp(s - m_glob[..., None])
    l = lax.psum(jnp.sum(p, axis=-1), axis_name)          # denominator
    num = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    num = lax.psum(num, axis_name)
    out = num / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, mesh,
                     seq_axis: str | None, scale=None,
                     window: int | None = None, softcap: float | None = None,
                     batch_axes=("data",)):
    """One-token attention against a sequence-sharded KV cache."""
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if seq_axis is None:
        # single-shard oracle path
        b, _, hq, d = q.shape
        hkv = k_cache.shape[2]
        g = hq // hkv
        qg = q.reshape(b, hkv, g, d)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = jnp.arange(k_cache.shape[1])[None, :]
        mask = kpos < length
        if window is not None:
            mask &= (length - 1 - kpos) < window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
        return out.reshape(b, 1, hq, d).astype(q.dtype)

    axes = (seq_axis,) if isinstance(seq_axis, str) else tuple(seq_axis)
    shape = dict(mesh.shape)
    axis_size = 1
    for a in axes:
        axis_size *= shape[a]
    fn = functools.partial(_decode_local, axis_name=axes,
                           axis_size=axis_size, scale=scale, window=window,
                           softcap=softcap)
    bspec = tuple(batch_axes) or None
    qspec = P(bspec, None, None, None)
    kvspec = P(bspec, axes, None, None)
    return shard_map(
        fn, mesh=mesh, in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=qspec)(q, k_cache, v_cache, length)


def cache_append(k_cache, v_cache, k_new, v_new, length, *, mesh,
                 seq_axis: str | None, batch_axes=("data",)):
    """Write the new token's K/V into position `length` of the sharded cache.

    Only the shard owning that position writes; others pass through.  Lowers
    to a masked scatter with no communication.
    """
    if seq_axis is None:
        k = lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), length, 1)
        v = lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), length, 1)
        return k, v

    axes = (seq_axis,) if isinstance(seq_axis, str) else tuple(seq_axis)

    def fn(kc, vc, kn, vn, pos):
        sl = kc.shape[1]
        idx = lax.axis_index(axes)
        local = jnp.clip(pos - idx * sl, 0, sl - 1)
        owns = (pos >= idx * sl) & (pos < (idx + 1) * sl)
        kupd = lax.dynamic_update_slice_in_dim(kc, kn.astype(kc.dtype), local, 1)
        vupd = lax.dynamic_update_slice_in_dim(vc, vn.astype(vc.dtype), local, 1)
        return (jnp.where(owns, kupd, kc), jnp.where(owns, vupd, vc))

    bspec = tuple(batch_axes) or None
    kvspec = P(bspec, axes, None, None)
    nspec = P(bspec, None, None, None)
    return shard_map(
        fn, mesh=mesh, in_specs=(kvspec, kvspec, nspec, nspec, P()),
        out_specs=(kvspec, kvspec))(k_cache, v_cache, k_new, v_new, length)
