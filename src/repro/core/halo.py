"""Halo exchange — the paper's core communication primitive (§III-A, §IV).

A tensor dimension is block-partitioned across a named mesh axis — or a
*tuple* of mesh axes forming one product axis (how 16x16 meshes split H over
two torus dimensions) — and each shard needs `lo` trailing rows of its
predecessor and `hi` leading rows of its successor (a stencil halo).  On TPU
this lowers to `collective-permute` on the ICI torus — the native
neighbor-exchange pattern.

``jax.lax.ppermute`` fills shards that receive nothing with zeros, which
implements the paper's "same" zero padding at the global boundary for free
(Eq. 1's out-of-range indices).

Product axes: when `axis_name` is a tuple, shard identity is the linearized
index over the named axes, major-to-minor in tuple order — the same
convention ``PartitionSpec((a, b))`` uses to lay blocks out — so the i -> i+1
neighbor permutation crosses axis boundaries correctly: the last shard of an
inner-axis row sends to the first shard of the next outer-axis row, exactly
as if H were split over one axis of the product size.  ``lax.ppermute`` and
``lax.axis_index`` both accept the tuple natively and agree on this
linearization.

These functions must be called inside ``shard_map`` (they use collectives on
`axis_name`).  They are fully differentiable: the VJP of ppermute is ppermute
with the inverted permutation, so autodiff produces exactly the paper's
backward halo pattern (halo exchange on dL/dy, send-back-and-accumulate of
boundary gradients).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import trace as trace_lib


def _fwd_perm(n: int):  # shard i -> i+1  (send my tail downward)
    return [(i, i + 1) for i in range(n - 1)]


def _bwd_perm(n: int):  # shard i -> i-1  (send my head upward)
    return [(i + 1, i) for i in range(n - 1)]


def axes_tuple(axis_name) -> tuple[str, ...]:
    """Normalize an axis spec (None | str | tuple of str) to a tuple."""
    if axis_name is None:
        return ()
    if isinstance(axis_name, str):
        return (axis_name,)
    return tuple(axis_name)


def product_size(axis_name, mesh_shape: Mapping[str, int]) -> int:
    """Total shard count of a (possibly product) axis under `mesh_shape`."""
    n = 1
    for a in axes_tuple(axis_name):
        n *= mesh_shape[a]
    return n


def halo_slices(x, dim: int, lo: int, hi: int, axis_name, axis_size: int):
    """Return (halo_lo, halo_hi) received from the neighbor shards.

    halo_lo: the last `lo` rows of the predecessor shard (zeros on shard 0).
    halo_hi: the first `hi` rows of the successor shard (zeros on the last).
    Either may be None when the corresponding width is 0.

    `axis_name` may be one mesh axis or a tuple of axes treated as a single
    product axis of total size `axis_size` (see module docstring).
    """
    halo_lo = halo_hi = None
    with trace_lib.annotate("halo_exchange"):
        if lo > 0:
            tail = lax.slice_in_dim(x, x.shape[dim] - lo, x.shape[dim],
                                    axis=dim)
            halo_lo = lax.ppermute(tail, axis_name, _fwd_perm(axis_size))
        if hi > 0:
            head = lax.slice_in_dim(x, 0, hi, axis=dim)
            halo_hi = lax.ppermute(head, axis_name, _bwd_perm(axis_size))
    return halo_lo, halo_hi


def halo_exchange(x, dim: int, lo: int, hi: int, axis_name,
                  axis_size: int, edge_value: float = 0.0):
    """Extend local block `x` along `dim` with its halo: (lo + local + hi).

    `edge_value` is the fill at the *global* boundary (shard 0's lo-halo and
    the last shard's hi-halo).  ppermute already yields zeros there; for a
    non-zero fill (e.g. -inf for max pooling) the edge shards overwrite it.
    `axis_name` may be a tuple (product axis); the boundary test then uses
    the linearized shard index, which axis_index computes for tuples.
    """
    halo_lo, halo_hi = halo_slices(x, dim, lo, hi, axis_name, axis_size)
    if halo_lo is not None and edge_value:
        idx = lax.axis_index(axis_name)
        halo_lo = jnp.where(idx == 0, jnp.full_like(halo_lo, edge_value),
                            halo_lo)
    if halo_hi is not None and edge_value:
        idx = lax.axis_index(axis_name)
        halo_hi = jnp.where(idx == axis_size - 1,
                            jnp.full_like(halo_hi, edge_value), halo_hi)
    parts = [p for p in (halo_lo, x, halo_hi) if p is not None]
    if len(parts) == 1:
        return x
    return lax.concatenate(parts, dimension=dim)


@jax.custom_vjp
def pinned(parts: tuple):
    """``lax.optimization_barrier`` as a differentiable identity.

    The primitive has no differentiation rule, but the barrier IS the
    identity — so the VJP barriers the cotangents instead, which pins the
    *mirrored* schedule into backprop: the boundary-gradient sends are
    ordered against the interior dL/dx exactly as the forward halos were
    ordered against the interior conv (§IV-A both directions).
    """
    return lax.optimization_barrier(parts)


def _pinned_fwd(parts):
    return lax.optimization_barrier(parts), None


def _pinned_bwd(_, cts):
    return (lax.optimization_barrier(tuple(cts)),)


pinned.defvjp(_pinned_fwd, _pinned_bwd)


class HaloSchedule:
    """Latency-hiding issue order for the §III-C halo transfers (§IV-A).

    Construction *issues* the halo ppermutes immediately — before any of
    the compute that will consume them is built — so the transfers sit at
    the top of the dataflow graph and the latency-hiding scheduler can
    start them while independent (interior) compute runs.  `pin(interior)`
    then ties the in-flight halo tensors to the interior result with
    ``jax.lax.optimization_barrier``: the compiler can neither sink the
    transfers back down past the interior conv nor hoist the boundary
    convs (the halo consumers) above it — the §IV-A interior-first
    schedule, pinned against reordering.  On TPU the ppermute is an async
    collective-permute the interior conv genuinely runs under; on host/GPU
    XLA the same dependence ordering lets the scheduler start the
    collective's rendezvous early.
    """

    def __init__(self, x, dim: int, lo: int, hi: int, axis_name,
                 axis_size: int):
        self.lo, self.hi = halo_slices(x, dim, lo, hi, axis_name, axis_size)

    def pin(self, interior):
        """Barrier `interior` together with the in-flight halo tensors.

        Returns (interior, halo_lo, halo_hi) with the issue order pinned:
        everything consuming the returned halos is scheduled after the
        interior result they were barriered with."""
        parts = [interior] + [p for p in (self.lo, self.hi) if p is not None]
        if len(parts) == 1:
            return interior, self.lo, self.hi
        out = list(pinned(tuple(parts)))
        interior = out.pop(0)
        lo = out.pop(0) if self.lo is not None else None
        hi = out.pop(0) if self.hi is not None else None
        return interior, lo, hi


def ring_shift(x, axis_name: str, axis_size: int, reverse: bool = False):
    """Full ring rotation (used by ring attention): shard i's block moves to
    shard i+1 (mod n).  Unlike the stencil halo this wraps around."""
    if reverse:
        perm = [(i, (i - 1) % axis_size) for i in range(axis_size)]
    else:
        perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    return lax.ppermute(x, axis_name, perm)
