"""Distributed-memory convolution with spatial decomposition (paper §III).

The input tensor (NHWC) is block-partitioned: N over the data axes (sample
parallelism), H — and optionally W — over mesh axes (spatial parallelism).
Each of H and W may be split over a *tuple* of mesh axes treated as one
product axis (core.halo's linearized-index convention) — the decomposition
16x16 meshes need when a single torus dimension is not enough ways.
Forward convolution needs a stencil halo of the neighbor shards' boundary
rows (paper Eq. 1 with restricted index sets); the halo exchange lowers to
``collective-permute`` on the TPU ICI torus.

Backpropagation is obtained by autodiff *through* the shard-local program:
the VJP of ``ppermute`` is the inverted ``ppermute``, so dL/dx receives
exactly the paper's halo exchange on dL/dy (Eq. 3) plus boundary-gradient
accumulation, and dL/dw is the local contraction (Eq. 2) completed by the
``psum`` that shard_map inserts for the replicated-weight cotangent — i.e.
the paper's allreduce.

Overlap (paper §IV-A): with ``overlap=True`` the local conv is split into an
interior block that depends only on local data and two boundary blocks that
consume the halo.  This makes the halo exchange and the interior convolution
*independent in dataflow*, which is what allows XLA's latency-hiding
scheduler to run the collective-permute concurrently with the interior conv
on TPU (the JAX analogue of the paper's separate cuDNN calls on interior and
boundary domains).  The same split in the transposed program hides the
dL/dx halo under the dL/dw contraction, which needs no halo (§IV-A).

All functions replicate single-device convolution exactly (up to float
accumulation order), as the paper requires.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import halo as halo_lib
from repro.core import trace as trace_lib
from repro.utils import cdiv, replication_policy, same_pads, shard_map

DIMNUMS = ("NHWC", "HWIO", "NHWC")


def cast_to_weight_dtype(x, w):
    """The repo-wide mixed-precision rule for conv layers: compute in the
    *weight* dtype.  Both conv runtimes (spatial_conv2d, channel_conv's
    cf_conv2d) apply this same rule, so a mixed sample/spatial/CF plan can
    never change numerics at a reshard boundary — every layer sees x in
    params' dtype regardless of which decomposition executes it."""
    return x.astype(w.dtype) if x.dtype != w.dtype else x


def fit_spatial_axis(size: int, axis, k: int, s: int,
                     mesh_shape: Mapping[str, int]):
    """The §III-A geometry test for one (possibly product) spatial axis:
    keep it only when every shard divides evenly, stays stride-aligned, and
    is at least kernel-sized; else None (the layer's spatial split demotes
    and the distribution change becomes a §III-C shuffle)."""
    if axis is None:
        return None
    m = halo_lib.product_size(axis, mesh_shape)
    good = size % m == 0 and (size // m) % s == 0 and size // m >= max(k, s)
    return axis if good else None


@dataclasses.dataclass(frozen=True)
class ConvSharding:
    """Distribution descriptor for a conv/pool layer (paper's D).

    batch_axes: mesh axes sharding N (sample parallelism).
    h_axis / w_axis: the mesh axis — or *tuple* of mesh axes forming one
        product axis (16x16-mesh splits, core.halo) — sharding H / W
        (spatial parallelism), or None.
    """
    batch_axes: tuple[str, ...] = ()
    h_axis: str | tuple[str, ...] | None = None
    w_axis: str | tuple[str, ...] | None = None

    @property
    def is_spatial(self) -> bool:
        return self.h_axis is not None or self.w_axis is not None

    @property
    def h_axes(self) -> tuple[str, ...]:
        return halo_lib.axes_tuple(self.h_axis)

    @property
    def w_axes(self) -> tuple[str, ...]:
        return halo_lib.axes_tuple(self.w_axis)

    @property
    def spatial_axes(self) -> tuple[str, ...]:
        """All mesh axes sharding H or W, flattened (BN psums, pooling)."""
        return self.h_axes + self.w_axes

    def x_spec(self) -> P:
        return P(self.batch_axes or None, self.h_axis, self.w_axis, None)

    def fit(self, h: int, w: int, k: int, s: int, mesh) -> "ConvSharding":
        """Drop spatial axes that this layer's geometry cannot support —
        the paper's 'spatial dimension ~ kernel size' edge case (§III-A):
        the layer falls back to sample parallelism and the distribution
        change between layers becomes a §III-C shuffle (resharding)."""
        if mesh is None or not self.is_spatial:
            return self
        shape = dict(mesh.shape)
        return dataclasses.replace(
            self, h_axis=fit_spatial_axis(h, self.h_axis, k, s, shape),
            w_axis=fit_spatial_axis(w, self.w_axis, k, s, shape))


def _conv_nhwc(x, w, strides, pads, backend: str = "xla",
               interior_first: bool = False):
    """Local dense conv — the per-shard compute the paper times as cuDNN.

    backend='pallas' routes through the implicit-GEMM MXU kernel
    (repro.kernels.conv2d).  That kernel computes VALID convolution with one
    stride for both spatial dims, so padding is materialized first and
    unequal strides fall back to XLA.  Off-TPU it runs in interpret mode
    (numerics-identical, for tests and CPU smoke runs).  `interior_first`
    asks the Pallas kernel for its §IV-A schedule (boundary row blocks
    visited last); the XLA route ignores it.
    """
    if backend == "pallas" and strides[0] == strides[1]:
        from repro.kernels.conv2d import conv2d as pallas_conv2d
        xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, 0)))
        return pallas_conv2d(xp, w, stride=strides[0],
                             interpret=jax.default_backend() != "tpu",
                             interior_first=interior_first)
    return lax.conv_general_dilated(
        x, w, window_strides=tuple(strides), padding=tuple(pads),
        dimension_numbers=DIMNUMS)


def _split_dim_conv(x, w, *, dim, s, k, lo, hi, axis_name, axis_size,
                    other_pads, stride_other, overlap, backend="xla"):
    """Conv along one sharded spatial `dim` (1=H or 2=W) of local block x.

    `other_pads`/`stride_other` apply to the other (unsharded) spatial dim.
    `axis_name` may be a tuple of mesh axes forming one product axis of
    total size `axis_size` (core.halo's linearized-index convention).
    Returns the local output block for this shard.
    """
    hl = x.shape[dim]
    assert hl % s == 0, f"local extent {hl} not divisible by stride {s}"
    assert hl >= k, (
        "spatial shard smaller than the kernel — the paper notes this edge "
        "case; use sample/channel parallelism for this layer instead")
    ho = hl // s

    def conv(z, pad_dim, interior_first=False):
        pads = [(0, 0), (0, 0)]
        pads[dim - 1] = pad_dim
        pads[2 - dim] = other_pads
        strides = [0, 0]
        strides[dim - 1] = s
        strides[2 - dim] = stride_other
        return _conv_nhwc(z, w, tuple(strides), tuple(pads), backend,
                          interior_first)

    if lo == 0 and hi == 0:
        return conv(x, (0, 0))

    # issue the halo transfers up front (§IV-A): every compute op below is
    # built AFTER the ppermutes, so the transfers head the dataflow graph.
    sched = halo_lib.HaloSchedule(x, dim, lo, hi, axis_name, axis_size)
    halo_lo, halo_hi = sched.lo, sched.hi

    if not overlap:
        parts = [p for p in (halo_lo, x, halo_hi) if p is not None]
        with trace_lib.annotate("conv_serialized"):
            return conv(lax.concatenate(parts, dimension=dim), (0, 0))

    # --- interior/boundary latency-hiding schedule (paper §IV-A) ---
    t_lo = cdiv(lo, s)                       # output rows needing the lo halo
    i_hi = cdiv(hl + lo - k + 1, s)          # first output row needing hi halo
    t_hi = ho - i_hi
    if t_lo + t_hi >= ho:                    # shard too small to split
        # no XLA-level split possible; when the halo rides along H the
        # Pallas kernel can still run its own interior-first block order.
        parts = [p for p in (halo_lo, x, halo_hi) if p is not None]
        with trace_lib.annotate("conv_serialized"):
            return conv(lax.concatenate(parts, dimension=dim), (0, 0),
                        interior_first=(dim == 1))

    # interior first: rows [t_lo, i_hi) read input [t_lo*s - lo,
    # (i_hi-1)s - lo + k) — no halo dependence, so this conv runs while the
    # transfers are in flight.  pin() then barriers the halos behind the
    # interior result, so the boundary convs cannot be hoisted above it
    # (nor the transfers sunk below it) by the compiler.
    inner_in = lax.slice_in_dim(
        x, t_lo * s - lo, (i_hi - 1) * s - lo + k, axis=dim)
    with trace_lib.annotate("conv_interior"):
        interior = conv(inner_in, (0, 0))
    interior, halo_lo, halo_hi = sched.pin(interior)

    blocks = []
    with trace_lib.annotate("conv_boundary"):
        if t_lo > 0:
            # top boundary: rows [0, t_lo) read input
            # [-lo, (t_lo-1)s - lo + k)
            top_in = lax.concatenate(
                [halo_lo,
                 lax.slice_in_dim(x, 0, (t_lo - 1) * s - lo + k, axis=dim)],
                dimension=dim)
            blocks.append(conv(top_in, (0, 0)))
        blocks.append(interior)
        if t_hi > 0:
            bot_in = lax.slice_in_dim(x, i_hi * s - lo, hl, axis=dim)
            bot_in = lax.concatenate([bot_in, halo_hi], dimension=dim)
            blocks.append(conv(bot_in, (0, 0)))
    return lax.concatenate(blocks, dimension=dim) if len(blocks) > 1 \
        else blocks[0]


def _local_conv(x, w, *, strides, sharding: ConvSharding, mesh_shape,
                overlap: bool, backend: str = "xla"):
    """Shard-local forward conv (runs inside shard_map)."""
    k_h, k_w = w.shape[0], w.shape[1]
    s_h, s_w = strides
    ph = same_pads(k_h, s_h)
    pw = same_pads(k_w, s_w)

    if sharding.h_axis is not None and sharding.w_axis is not None:
        # shard H first (halo on H incl. full local W), then W.
        x = halo_lib.halo_exchange(
            x, 1, ph[0], ph[1], sharding.h_axis,
            halo_lib.product_size(sharding.h_axis, mesh_shape))
        return _split_dim_conv(
            x, w, dim=2, s=s_w, k=k_w, lo=pw[0], hi=pw[1],
            axis_name=sharding.w_axis,
            axis_size=halo_lib.product_size(sharding.w_axis, mesh_shape),
            other_pads=(0, 0), stride_other=s_h, overlap=overlap,
            backend=backend)
    if sharding.h_axis is not None:
        return _split_dim_conv(
            x, w, dim=1, s=s_h, k=k_h, lo=ph[0], hi=ph[1],
            axis_name=sharding.h_axis,
            axis_size=halo_lib.product_size(sharding.h_axis, mesh_shape),
            other_pads=pw, stride_other=s_w, overlap=overlap,
            backend=backend)
    if sharding.w_axis is not None:
        return _split_dim_conv(
            x, w, dim=2, s=s_w, k=k_w, lo=pw[0], hi=pw[1],
            axis_name=sharding.w_axis,
            axis_size=halo_lib.product_size(sharding.w_axis, mesh_shape),
            other_pads=ph, stride_other=s_h, overlap=overlap,
            backend=backend)
    raise AssertionError("not spatial")


def spatial_conv2d(x, w, *, strides=(1, 1), sharding: ConvSharding,
                   mesh=None, overlap: bool = True, backend: str = "xla"):
    """'SAME'-padded strided conv2d under hybrid sample/spatial parallelism.

    x: (N, H, W, C) global array (sharded per `sharding` under jit).
    w: (K_h, K_w, C, F) weights, replicated across the spatial/batch axes
       (FSDP resharding at the shard_map boundary gathers them if needed).
    backend: 'xla' (default) or 'pallas' — which kernel runs the local conv
       each shard computes after its halo exchange (see _conv_nhwc).
    """
    x = cast_to_weight_dtype(x, w)   # the repo-wide mixed-precision rule
    if not sharding.is_spatial:
        # pure sample parallelism: local conv, XLA batches it (paper Fig 1a).
        k_h, k_w = w.shape[0], w.shape[1]
        y = _conv_nhwc(x, w, strides,
                       (same_pads(k_h, strides[0]),
                        same_pads(k_w, strides[1])), backend)
        if mesh is not None:
            y = lax.with_sharding_constraint(
                y, jax.sharding.NamedSharding(mesh, sharding.x_spec()))
        return y

    mesh = mesh or jax.sharding.get_abstract_mesh()
    mesh_shape = dict(mesh.shape)
    fn = functools.partial(_local_conv, strides=strides, sharding=sharding,
                           mesh_shape=mesh_shape, overlap=overlap,
                           backend=backend)
    spec = sharding.x_spec()
    # one repo-wide replication policy per backend (utils.replication_policy;
    # the static auditor reports which policy each region compiled under)
    policy = replication_policy(backend)
    return shard_map(fn, mesh=mesh, in_specs=(spec, P()), out_specs=spec,
                     legacy_check_rep=policy.legacy_check_rep)(x, w)


# ---------------------------------------------------------------------------
# Pooling under spatial decomposition (paper §III-B: "parallelized similarly")
# ---------------------------------------------------------------------------

def _local_pool(x, *, window, strides, sharding: ConvSharding, mesh_shape,
                kind: str):
    k_h, k_w = window
    s_h, s_w = strides
    ph = same_pads(k_h, s_h)
    pw = same_pads(k_w, s_w)
    edge = float("-inf") if kind == "max" else 0.0

    pads = [(0, 0), ph, pw, (0, 0)]
    if sharding.h_axis is not None:
        x = halo_lib.halo_exchange(
            x, 1, ph[0], ph[1], sharding.h_axis,
            halo_lib.product_size(sharding.h_axis, mesh_shape),
            edge_value=edge)
        pads[1] = (0, 0)
    if sharding.w_axis is not None:
        x = halo_lib.halo_exchange(
            x, 2, pw[0], pw[1], sharding.w_axis,
            halo_lib.product_size(sharding.w_axis, mesh_shape),
            edge_value=edge)
        pads[2] = (0, 0)
    return _pool_windows(x, window, strides, tuple(pads), kind)


def _pool_windows(x, window, strides, pads, kind):
    """Pooling via stacked shifted slices + reduce over the window axis —
    fully reverse-differentiable (reduce_window's max transpose is not
    supported under shard_map's manual axes)."""
    k_h, k_w = window
    s_h, s_w = strides
    edge = jnp.asarray(float("-inf") if kind == "max" else 0.0, x.dtype)
    x = jnp.pad(x, pads, constant_values=edge)
    h_out = (x.shape[1] - k_h) // s_h + 1
    w_out = (x.shape[2] - k_w) // s_w + 1
    taps = []
    for i in range(k_h):
        for j in range(k_w):
            taps.append(x[:, i:i + h_out * s_h:s_h,
                          j:j + w_out * s_w:s_w, :])
    stack = jnp.stack(taps, axis=-1)
    if kind == "max":
        return jnp.max(stack, axis=-1)
    return jnp.sum(stack, axis=-1) / (k_h * k_w)


def spatial_pool(x, *, window=(3, 3), strides=(2, 2),
                 sharding: ConvSharding, mesh=None, kind: str = "max"):
    """'SAME' max/avg pool under the same decomposition as spatial_conv2d.

    Max pooling fills the *global-edge* halo with -inf (not the zeros that
    ppermute produces) so edge windows match single-device 'SAME' semantics.
    Avg pooling uses count_include_pad=True (zero pad), matching the oracle in
    models/cnn/layers.py.
    """
    if not sharding.is_spatial:
        k_h, k_w = window
        s_h, s_w = strides
        return _pool_windows(
            x, window, strides,
            ((0, 0), same_pads(k_h, s_h), same_pads(k_w, s_w), (0, 0)),
            kind)

    mesh = mesh or jax.sharding.get_abstract_mesh()
    fn = functools.partial(_local_pool, window=window, strides=strides,
                           sharding=sharding, mesh_shape=dict(mesh.shape),
                           kind=kind)
    spec = sharding.x_spec()
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(x)
