"""Train-step builders: mixed precision, gradient accumulation
(micro-batching), remat, cross-pod gradient compression.

`make_train_step` builds one jit-compiled SPMD step.  Sharding is pjit-style:
the caller provides PartitionSpecs for params and batch; the paper's
fine-grained primitives (spatial conv / ring attention / ...) live inside
the loss function as shard_map islands.

Gradient accumulation implements the out-of-core "micro-batching" the paper
cites ([43], §VII Memory pressure): the global batch is split into
`grad_accum` micro-batches scanned sequentially, trading time for activation
memory — composable with spatial parallelism, which shrinks per-sample
memory instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.grad_compress import cross_pod_mean
from repro.optim.optimizer import Optimizer
from repro.utils import Precision, BF16


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    grad_accum: int = 1
    precision: Precision = BF16
    remat: bool = False                  # rematerialize the loss fn
    pod_compression: str = "none"        # none | bf16 | int8_ef


def make_train_step(loss_fn: Callable, opt: Optimizer, mesh,
                    cfg: TrainStepConfig = TrainStepConfig()):
    """loss_fn(params, batch) -> scalar loss (params in compute dtype).

    Returns step(params, opt_state, ef_state, batch) ->
            (params, opt_state, ef_state, metrics).
    """
    lfn = jax.checkpoint(loss_fn) if cfg.remat else loss_fn

    def fwd_bwd(params, batch):
        cparams = cfg.precision.cast_compute(params)
        loss, grads = jax.value_and_grad(lfn)(cparams, batch)
        # master-dtype grads for the optimizer
        grads = jax.tree.map(
            lambda g, p: g.astype(p.dtype), grads, params)
        return loss, grads

    def step(params, opt_state, ef_state, batch):
        if cfg.grad_accum > 1:
            def split(x):
                return x.reshape((cfg.grad_accum,
                                  x.shape[0] // cfg.grad_accum) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                loss, grads = fwd_bwd(params, mb)
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_grads, grads)), None

            zero = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(body, (0.0, zero), micro)
            loss = loss / cfg.grad_accum
            grads = jax.tree.map(lambda g: g / cfg.grad_accum, grads)
        else:
            loss, grads = fwd_bwd(params, batch)

        if cfg.pod_compression != "none" and "pod" in mesh.axis_names:
            grads, ef_state = cross_pod_mean(
                grads, mesh=mesh, method=cfg.pod_compression,
                error_feedback=ef_state)

        new_params, new_opt = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, ef_state, {"loss": loss,
                                               "grad_norm": gnorm}

    return jax.jit(step, donate_argnums=(0, 1, 2))


def shard_tree(tree, mesh, spec_fn: Callable[[Any], P]):
    """device_put every leaf with the sharding given by spec_fn(leaf)."""
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, spec_fn(x))), tree)


def fsdp_spec_for(shape, mesh_axis_size: int, axis: str = "data",
                  min_size: int = 2 ** 14) -> P:
    """ZeRO/FSDP rule: shard the largest evenly-divisible dim of every
    big tensor over the data axis; small tensors stay replicated."""
    size = 1
    for s in shape:
        size *= s
    if not shape or size < min_size:
        return P()
    for d in sorted(range(len(shape)), key=lambda d: -shape[d]):
        if shape[d] % mesh_axis_size == 0:
            spec = [None] * len(shape)
            spec[d] = axis
            return P(*spec)
    return P()
