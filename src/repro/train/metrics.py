"""Structured training telemetry — JSONL step records + NaN debugging.

`MetricsLogger` replaces the train driver's ad-hoc prints: every step (or
every `log_every`-th) emits one JSON line a dashboard or notebook can load
with `json.loads` per line — loss, wall-clock step time, samples/s, and any
extra fields the caller attaches (per-layer attribution, predicted peak
memory, achieved overlap η).  A human-readable echo keeps the terminal
experience of the old prints.

`debug_nan_check` backs the ``--debug-nans`` train flag: it inspects the
step's host-side metrics (loss, grad_norm — already synced floats, so the
per-step check is free) and, on the first non-finite value, scans the
parameter list layer by layer with `utils.assert_no_nans` to *name* the
first offending layer (the trace layer names of models.cnn.meshnet), so a
blown-up run points at a layer instead of at "loss is nan".
"""
from __future__ import annotations

import json
import math
import time
from typing import IO, Mapping, Sequence

SCHEMA = "repro/metrics@1"


class MetricsLogger:
    """JSONL step-record writer with a human-readable echo.

    path: JSONL output file (None = echo only).  Lines are objects with a
          "kind" field: one "run" header (schema, run metadata), then one
          "step" record per logged step, then a "done" footer.
    echo: also print a terminal line per record (the old driver output).
    """

    def __init__(self, path: str | None = None, echo: bool = True):
        self.path = path
        self.echo = echo
        self._f: IO | None = open(path, "w") if path else None
        self._t0 = time.time()
        self._n = 0

    # -- records ------------------------------------------------------------
    def _emit(self, rec: Mapping) -> None:
        if self._f is not None:
            self._f.write(json.dumps(rec, sort_keys=True) + "\n")
            self._f.flush()
        self._n += 1

    def log_run(self, **meta) -> None:
        """The run header: arch, mesh, plan summary, predicted costs..."""
        self._emit({"kind": "run", "schema": SCHEMA,
                    "time": time.time(), **meta})
        if self.echo and meta:
            parts = " ".join(f"{k}={v}" for k, v in meta.items()
                             if not isinstance(v, (dict, list)))
            print(parts)

    def log_step(self, step: int, loss: float, *,
                 step_time_s: float | None = None,
                 samples_per_s: float | None = None,
                 echo: bool | None = None, **extra) -> None:
        rec = {"kind": "step", "step": step, "loss": float(loss)}
        if step_time_s is not None:
            rec["step_time_s"] = step_time_s
        if samples_per_s is not None:
            rec["samples_per_s"] = samples_per_s
        rec.update(extra)
        self._emit(rec)
        if self.echo if echo is None else echo:
            tail = f" ({step_time_s:.3f}s/step" if step_time_s else "("
            if samples_per_s:
                tail += f", {samples_per_s:.1f} samples/s"
            tail += ")" if step_time_s or samples_per_s else ""
            print(f"step {step:5d} loss {float(loss):.4f} {tail}".rstrip())

    def log_event(self, kind: str, **fields) -> None:
        """A free-form record (checkpoint saved, straggler, profile...)."""
        self._emit({"kind": kind, "time": time.time(), **fields})

    def log_done(self, step: int, **fields) -> None:
        self._emit({"kind": "done", "step": step,
                    "wall_s": time.time() - self._t0, **fields})

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def debug_nan_check(step: int, metrics: Mapping, params=None,
                    layer_names: Sequence[str] | None = None) -> None:
    """Raise FloatingPointError on the first non-finite loss/grad_norm.

    The per-step check reads only host-side metric floats (free).  When it
    trips and `params` is given, the parameter list is scanned layer by
    layer (`layer_names` aligned with a models.cnn list layout; any other
    pytree is scanned whole) with `utils.assert_no_nans`, whose keypath
    message names the first offending layer and parameter.
    """
    bad = [k for k in ("loss", "grad_norm")
           if k in metrics and not math.isfinite(float(metrics[k]))]
    if not bad:
        return
    head = f"--debug-nans: non-finite {'/'.join(bad)} at step {step}"
    if params is not None:
        from repro.utils import assert_no_nans
        if (layer_names is not None and isinstance(params, (list, tuple))
                and len(layer_names) == len(params)):
            pairs = list(zip(layer_names, params))
        else:
            pairs = [("params", params)]
        for name, p in pairs:
            try:
                assert_no_nans(p, where=f"layer {name!r} ")
            except AssertionError as e:
                raise FloatingPointError(f"{head}; {e}") from None
    raise FloatingPointError(
        f"{head}; parameters are all finite (transient in the loss/grad "
        "path — rerun with a lower lr or inspect the batch)")
