"""Optimizers as pure pytree transforms (no external deps).

SGD-momentum (the paper's CNN training) and AdamW (LM substrate), with
warmup+cosine schedules and global-norm clipping.  Optimizer state inherits
the parameters' sharding (ZeRO-1 falls out of FSDP-sharded params: each
device only materializes its shard of momentum/variance).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment / momentum
    nu: Any          # second moment (None for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def sgd(lr: float | Callable, momentum: float = 0.9,
        weight_decay: float = 0.0, nesterov: bool = False,
        clip_norm: float | None = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(jnp.zeros_like, params), None)

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p,
                                 grads, params)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        upd = jax.tree.map(lambda m, g: momentum * m + g, mu, grads) \
            if nesterov else mu
        step = state.step + 1
        lrv = lr_fn(step)
        new = jax.tree.map(lambda p, u: p - lrv * u, params, upd)
        return new, OptState(step, mu, None)

    return Optimizer(init, update)


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lrv = lr_fn(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p - lrv * (u + weight_decay * p.astype(jnp.float32))
                    .astype(p.dtype)).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, nu)
        return new, OptState(step, mu, nu)

    return Optimizer(init, update)
