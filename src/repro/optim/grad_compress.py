"""Gradient compression for cross-pod data-parallel reduction.

On the multi-pod mesh the `pod` axis crosses the slow DCN fabric; the
gradient allreduce there is the dominant inter-pod collective.  Two
compressors:

  * bf16: cast-reduce-cast (2x), error-free in practice for gradients.
  * int8 + error feedback: per-tensor-block scale, residual carried in the
    optimizer state so quantization error is re-injected next step (1-bit
    Adam-style EF); 4x over fp32, 2x over bf16.

The compressed reduction runs in a *partial-manual* shard_map: manual over
`pod` only, so the intra-pod program stays under the automatic partitioner
while the pod reduction is an explicit psum over quantized payloads with
fp32 accumulation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.utils import pcast_varying, shard_map


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def cross_pod_mean(grads, *, mesh, method: str = "bf16",
                   error_feedback: Any = None):
    """Average gradients across the pod axis with optional compression.

    grads: pytree of per-pod gradients (replicated/sharded over data/model,
    varying over pod).  Returns (reduced grads, new error-feedback state).
    """
    if "pod" not in mesh.axis_names:
        return grads, error_feedback
    npods = dict(mesh.shape)["pod"]

    def _vary(x):
        # psum of a pod-INVARIANT operand crashes this XLA version
        # ("Invalid binary instruction opcode copy"); marking the operand
        # varying first is free and matches the real (per-pod grads) use.
        return pcast_varying(x, ("pod",))

    if method == "none":
        f = lambda g: jax.tree.map(
            lambda x: lax.psum(_vary(x), "pod") / npods, g)
        out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                        axis_names={"pod"})(grads)
        return out, error_feedback

    if method == "bf16":
        # bf16 payload on the wire via all-gather + local fp32 mean (the
        # bf16 all-reduce instruction itself crashes this XLA CPU build).
        def f(g):
            def one(x):
                xs = lax.all_gather(_vary(x.astype(jnp.bfloat16)), "pod")
                return (jnp.sum(xs.astype(jnp.float32), 0)
                        / npods).astype(x.dtype)
            return jax.tree.map(one, g)
        out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                        axis_names={"pod"}, check_vma=False)(grads)
        return out, error_feedback

    if method == "int8_ef":
        # The EF residual is genuinely per-pod state: it carries a leading
        # pod dimension sharded over the pod axis.
        if error_feedback is None:
            error_feedback = jax.tree.map(
                lambda g: jnp.zeros((npods,) + g.shape, jnp.float32), grads)

        def f(g, ef):
            def one(x, e):
                x32 = x.astype(jnp.float32) + e[0]
                q, scale = _quantize_int8(x32)
                new_e = x32 - _dequantize(q, scale)  # residual, next step
                # true int8 payload on the wire: all-gather the quantized
                # blocks + their scales, dequantize and average locally.
                qs = lax.all_gather(q, "pod")            # (npods, ...)
                ss = lax.all_gather(scale, "pod")        # (npods,)
                red = jnp.mean(
                    qs.astype(jnp.float32)
                    * ss.reshape((npods,) + (1,) * x.ndim), axis=0)
                # every pod computes the identical mean of identical
                # gathered payloads, so the result is pod-invariant by
                # construction (check_vma can't prove this -> disabled).
                return red.astype(x.dtype), new_e[None]
            flat, treedef = jax.tree.flatten(g)
            eflat = jax.tree.leaves(ef)
            out = [one(x, e) for x, e in zip(flat, eflat)]
            return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                    jax.tree.unflatten(treedef, [o[1] for o in out]))

        efspec = jax.tree.map(lambda _: P("pod"), grads)
        gspec = jax.tree.map(lambda _: P(), grads)
        out, new_ef = shard_map(
            f, mesh=mesh, in_specs=(gspec, efspec), out_specs=(gspec, efspec),
            axis_names={"pod"}, check_vma=False)(grads, error_feedback)
        return out, new_ef

    raise ValueError(f"unknown compression method {method!r}")
